//! Serving metrics: latency percentiles, throughput, batch occupancy.

use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<u128>,
    batches: u64,
    requests: u64,
    rejected: u64,
    occupancy_sum: u64,
    started: Option<Instant>,
    // KV-cache session counters (token granularity)
    cache_hit_tokens: u64,
    cache_miss_tokens: u64,
    session_requests: u64,
    // absolute pool gauges, refreshed at each session admission
    cache_bytes: u64,
    cache_evictions: u64,
    // per-request CPU kernel timings from the backend's blocked
    // XNOR-popcount scoring inside batch decode
    kernel_us: Vec<u128>,
    // per-request total backend decode time (kernel + projections/MLP)
    decode_us: Vec<u128>,
    // generation streams (continuous batching): admission -> first token
    ttft_us: Vec<u128>,
    // gaps between consecutive generated tokens within a stream
    inter_token_us: Vec<u128>,
    gen_streams: u64,
    gen_tokens: u64,
    gen_budget_stops: u64,
    // generation-only clock: first and latest token emission, so the
    // throughput snapshot measures the generating span, not whatever
    // else happened before the first stream or after the last token
    gen_started: Option<Instant>,
    gen_last: Option<Instant>,
}

use crate::util::bench::percentile_us as pct;

/// Thread-safe metrics sink shared by batcher and server threads.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    pub p50_us: u128,
    pub p90_us: u128,
    pub p99_us: u128,
    pub mean_us: f64,
    pub mean_occupancy: f64,
    pub throughput_rps: f64,
    /// requests admitted through the session path
    pub session_requests: u64,
    /// tokens served from resident KV pages across all session admissions
    pub cache_hit_tokens: u64,
    /// tokens packed cold at admission
    pub cache_miss_tokens: u64,
    /// hit_tokens / (hit_tokens + miss_tokens); 0 with no session traffic
    pub cache_hit_rate: f64,
    /// resident pool bytes at the last admission
    pub cache_bytes: u64,
    /// cumulative pool evictions at the last admission
    pub cache_evictions: u64,
    /// popcount backend every kernel request dispatched through
    /// (`binary::simd::KernelBackend::active`, `HAD_KERNEL` override)
    pub kernel_backend: &'static str,
    /// CPU features detected on this host (e.g. "x86_64: popcnt avx2")
    pub cpu_features: String,
    /// requests scored by the CPU kernel during batch decode
    pub kernel_requests: u64,
    /// per-request kernel time percentiles/mean (µs; 0 with no kernel traffic)
    pub kernel_p50_us: u128,
    pub kernel_p99_us: u128,
    pub kernel_mean_us: f64,
    /// requests decoded end-to-end by the CPU serving backend
    pub decode_requests: u64,
    /// per-request backend decode time percentiles/mean (µs)
    pub decode_p50_us: u128,
    pub decode_p99_us: u128,
    pub decode_mean_us: f64,
    /// generation streams retired by the continuous-batching scheduler
    pub gen_streams: u64,
    /// tokens generated across all streams
    pub gen_tokens: u64,
    /// streams retired by context/KV budget pressure (StopReason::Budget)
    pub gen_budget_stops: u64,
    /// time-to-first-token percentiles/mean (µs; admission -> emission)
    pub ttft_p50_us: u128,
    pub ttft_p99_us: u128,
    pub ttft_mean_us: f64,
    /// inter-token latency percentiles/mean (µs; 0 with no multi-token streams)
    pub inter_token_p50_us: u128,
    pub inter_token_p99_us: u128,
    pub inter_token_mean_us: f64,
    /// generated tokens per second of serving wall time
    pub gen_tokens_per_s: f64,
}

impl Metrics {
    pub fn record_batch(&self, latencies_us: &[u128], occupancy: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
        g.latencies_us.extend_from_slice(latencies_us);
        g.requests += latencies_us.len() as u64;
        g.batches += 1;
        g.occupancy_sum += occupancy as u64;
    }

    pub fn record_reject(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One session admission: `hit_tokens` were already resident,
    /// `miss_tokens` were packed cold this turn.
    pub fn record_session(&self, hit_tokens: usize, miss_tokens: usize) {
        let mut g = self.inner.lock().unwrap();
        g.session_requests += 1;
        g.cache_hit_tokens += hit_tokens as u64;
        g.cache_miss_tokens += miss_tokens as u64;
    }

    /// Refresh the pool gauges (absolute values, taken after admission).
    pub fn update_cache_pool(&self, bytes: usize, evictions: u64) {
        let mut g = self.inner.lock().unwrap();
        g.cache_bytes = bytes as u64;
        g.cache_evictions = evictions;
    }

    /// One request's share of batch decode: the CPU time the blocked
    /// XNOR-popcount kernel spent scoring its segment.
    pub fn record_kernel(&self, us: u128) {
        self.inner.lock().unwrap().kernel_us.push(us);
    }

    /// One request's total backend decode time (its suffix's forward).
    pub fn record_decode(&self, us: u128) {
        self.inner.lock().unwrap().decode_us.push(us);
    }

    /// A stream's first generated token: `us` since admission (TTFT —
    /// includes queueing, activation, and the prefill decode).
    pub fn record_first_token(&self, us: u128) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        if g.gen_started.is_none() {
            g.gen_started = Some(now);
        }
        g.gen_last = Some(now);
        g.ttft_us.push(us);
        g.gen_tokens += 1;
    }

    /// Gap between consecutive generated tokens of one stream.
    pub fn record_inter_token(&self, us: u128) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        if g.gen_started.is_none() {
            g.gen_started = Some(now);
        }
        g.gen_last = Some(now);
        g.inter_token_us.push(us);
        g.gen_tokens += 1;
    }

    /// A generation stream retired (`budget`: stopped by context or KV
    /// byte pressure rather than its own stop conditions).
    pub fn record_stream_retired(&self, budget: bool) {
        let mut g = self.inner.lock().unwrap();
        g.gen_streams += 1;
        if budget {
            g.gen_budget_stops += 1;
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_unstable();
        let mut kern = g.kernel_us.clone();
        kern.sort_unstable();
        let mut dec = g.decode_us.clone();
        dec.sort_unstable();
        let mut ttft = g.ttft_us.clone();
        ttft.sort_unstable();
        let mut inter = g.inter_token_us.clone();
        inter.sort_unstable();
        let elapsed = g.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            rejected: g.rejected,
            p50_us: pct(&lat, 0.50),
            p90_us: pct(&lat, 0.90),
            p99_us: pct(&lat, 0.99),
            mean_us: if lat.is_empty() {
                0.0
            } else {
                lat.iter().sum::<u128>() as f64 / lat.len() as f64
            },
            mean_occupancy: if g.batches == 0 {
                0.0
            } else {
                g.occupancy_sum as f64 / g.batches as f64
            },
            throughput_rps: if elapsed > 0.0 { g.requests as f64 / elapsed } else { 0.0 },
            session_requests: g.session_requests,
            cache_hit_tokens: g.cache_hit_tokens,
            cache_miss_tokens: g.cache_miss_tokens,
            cache_hit_rate: {
                let total = g.cache_hit_tokens + g.cache_miss_tokens;
                if total == 0 {
                    0.0
                } else {
                    g.cache_hit_tokens as f64 / total as f64
                }
            },
            cache_bytes: g.cache_bytes,
            cache_evictions: g.cache_evictions,
            kernel_backend: crate::binary::KernelBackend::active().name(),
            cpu_features: crate::binary::simd::cpu_features(),
            kernel_requests: kern.len() as u64,
            kernel_p50_us: pct(&kern, 0.50),
            kernel_p99_us: pct(&kern, 0.99),
            kernel_mean_us: if kern.is_empty() {
                0.0
            } else {
                kern.iter().sum::<u128>() as f64 / kern.len() as f64
            },
            decode_requests: dec.len() as u64,
            decode_p50_us: pct(&dec, 0.50),
            decode_p99_us: pct(&dec, 0.99),
            decode_mean_us: if dec.is_empty() {
                0.0
            } else {
                dec.iter().sum::<u128>() as f64 / dec.len() as f64
            },
            gen_streams: g.gen_streams,
            gen_tokens: g.gen_tokens,
            gen_budget_stops: g.gen_budget_stops,
            ttft_p50_us: pct(&ttft, 0.50),
            ttft_p99_us: pct(&ttft, 0.99),
            ttft_mean_us: if ttft.is_empty() {
                0.0
            } else {
                ttft.iter().sum::<u128>() as f64 / ttft.len() as f64
            },
            inter_token_p50_us: pct(&inter, 0.50),
            inter_token_p99_us: pct(&inter, 0.99),
            inter_token_mean_us: if inter.is_empty() {
                0.0
            } else {
                inter.iter().sum::<u128>() as f64 / inter.len() as f64
            },
            gen_tokens_per_s: {
                // first-to-last token span: excludes pre-stream traffic
                // and anything after the final token (0 until a second
                // token makes the span non-degenerate)
                let span = match (g.gen_started, g.gen_last) {
                    (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
                    _ => 0.0,
                };
                if span > 0.0 {
                    g.gen_tokens as f64 / span
                } else {
                    0.0
                }
            },
        }
    }
}

impl Snapshot {
    pub fn print(&self, label: &str) {
        println!(
            "{label}: {} reqs in {} batches (occ {:.2}), rejected {} | latency p50 {:.2} ms p90 {:.2} ms p99 {:.2} ms mean {:.2} ms | {:.1} req/s",
            self.requests,
            self.batches,
            self.mean_occupancy,
            self.rejected,
            self.p50_us as f64 / 1e3,
            self.p90_us as f64 / 1e3,
            self.p99_us as f64 / 1e3,
            self.mean_us / 1e3,
            self.throughput_rps,
        );
        if self.session_requests > 0 {
            println!(
                "{label}: kv-cache: {} session reqs | {} hit / {} miss tokens ({:.1}% hit) | {} KiB resident, {} evictions",
                self.session_requests,
                self.cache_hit_tokens,
                self.cache_miss_tokens,
                100.0 * self.cache_hit_rate,
                self.cache_bytes / 1024,
                self.cache_evictions,
            );
        }
        if self.kernel_requests > 0 {
            println!(
                "{label}: kernel: {} reqs scored | p50 {:.2} ms p99 {:.2} ms mean {:.2} ms per request | backend {} ({})",
                self.kernel_requests,
                self.kernel_p50_us as f64 / 1e3,
                self.kernel_p99_us as f64 / 1e3,
                self.kernel_mean_us / 1e3,
                self.kernel_backend,
                self.cpu_features,
            );
        }
        if self.gen_streams > 0 || self.gen_tokens > 0 {
            println!(
                "{label}: generate: {} streams, {} tokens ({} budget-stopped) | ttft p50 {:.2} ms p99 {:.2} ms | inter-token p50 {:.2} ms p99 {:.2} ms | {:.1} tok/s",
                self.gen_streams,
                self.gen_tokens,
                self.gen_budget_stops,
                self.ttft_p50_us as f64 / 1e3,
                self.ttft_p99_us as f64 / 1e3,
                self.inter_token_p50_us as f64 / 1e3,
                self.inter_token_p99_us as f64 / 1e3,
                self.gen_tokens_per_s,
            );
        }
        if self.decode_requests > 0 {
            let share = if self.decode_mean_us > 0.0 {
                100.0 * self.kernel_mean_us / self.decode_mean_us
            } else {
                0.0
            };
            println!(
                "{label}: decode: {} reqs served | p50 {:.2} ms p99 {:.2} ms mean {:.2} ms per request | kernel share {share:.1}%",
                self.decode_requests,
                self.decode_p50_us as f64 / 1e3,
                self.decode_p99_us as f64 / 1e3,
                self.decode_mean_us / 1e3,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let m = Metrics::default();
        let lats: Vec<u128> = (1..=100).collect();
        m.record_batch(&lats, 8);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 1);
        assert_eq!(s.p50_us, 51);
        assert_eq!(s.p99_us, 100);
        assert!((s.mean_us - 50.5).abs() < 1e-9);
    }

    #[test]
    fn occupancy_and_rejections() {
        let m = Metrics::default();
        m.record_batch(&[10, 10], 2);
        m.record_batch(&[10, 10, 10, 10], 4);
        m.record_reject();
        let s = m.snapshot();
        assert_eq!(s.rejected, 1);
        assert!((s.mean_occupancy - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.cache_hit_rate, 0.0);
    }

    #[test]
    fn kernel_timings() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().kernel_requests, 0);
        for us in [10u128, 20, 30, 40] {
            m.record_kernel(us);
        }
        let s = m.snapshot();
        assert_eq!(s.kernel_requests, 4);
        assert_eq!(s.kernel_p50_us, 30);
        assert_eq!(s.kernel_p99_us, 40);
        assert!((s.kernel_mean_us - 25.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_reports_kernel_backend_and_features() {
        use crate::binary::KernelBackend;
        let s = Metrics::default().snapshot();
        assert!(
            KernelBackend::available().iter().any(|b| b.name() == s.kernel_backend),
            "snapshot backend {:?} not in the available set",
            s.kernel_backend
        );
        assert!(s.cpu_features.contains(std::env::consts::ARCH));
    }

    #[test]
    fn decode_timings() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().decode_requests, 0);
        for us in [100u128, 200, 300, 400] {
            m.record_decode(us);
        }
        let s = m.snapshot();
        assert_eq!(s.decode_requests, 4);
        assert_eq!(s.decode_p50_us, 300);
        assert_eq!(s.decode_p99_us, 400);
        assert!((s.decode_mean_us - 250.0).abs() < 1e-9);
    }

    #[test]
    fn generation_timings() {
        let m = Metrics::default();
        let empty = m.snapshot();
        assert_eq!((empty.gen_streams, empty.gen_tokens), (0, 0));
        assert_eq!(empty.ttft_p50_us, 0);
        assert_eq!(empty.gen_tokens_per_s, 0.0);
        // two streams: 3 + 2 tokens (a real gap so the first-to-last
        // token span is non-degenerate)
        m.record_first_token(500);
        m.record_inter_token(40);
        m.record_inter_token(60);
        m.record_stream_retired(false);
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.record_first_token(900);
        m.record_inter_token(80);
        m.record_stream_retired(true);
        let s = m.snapshot();
        assert_eq!(s.gen_streams, 2);
        assert_eq!(s.gen_tokens, 5);
        assert_eq!(s.gen_budget_stops, 1);
        assert_eq!(s.ttft_p50_us, 900);
        assert_eq!(s.ttft_p99_us, 900);
        assert!((s.ttft_mean_us - 700.0).abs() < 1e-9);
        assert_eq!(s.inter_token_p50_us, 60);
        assert_eq!(s.inter_token_p99_us, 80);
        assert!((s.inter_token_mean_us - 60.0).abs() < 1e-9);
        assert!(s.gen_tokens_per_s > 0.0, "throughput clock started");
        // throughput measures the first-to-last TOKEN span: idle time
        // between the last token and the snapshot must not deflate it
        std::thread::sleep(std::time::Duration::from_millis(200));
        let late = m.snapshot();
        assert!(
            late.gen_tokens_per_s > 25.0,
            "post-generation idle time deflated throughput: {}",
            late.gen_tokens_per_s
        );
    }

    #[test]
    fn cache_counters() {
        let m = Metrics::default();
        m.record_session(0, 128); // cold first turn
        m.record_session(128, 16); // warm follow-up
        m.record_session(144, 16);
        m.update_cache_pool(4096, 1);
        let s = m.snapshot();
        assert_eq!(s.session_requests, 3);
        assert_eq!(s.cache_hit_tokens, 272);
        assert_eq!(s.cache_miss_tokens, 160);
        let want = 272.0 / (272.0 + 160.0);
        assert!((s.cache_hit_rate - want).abs() < 1e-12);
        assert_eq!((s.cache_bytes, s.cache_evictions), (4096, 1));
    }
}
