//! Serving metrics: latency percentiles, throughput, batch occupancy.

use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<u128>,
    batches: u64,
    requests: u64,
    rejected: u64,
    occupancy_sum: u64,
    started: Option<Instant>,
}

/// Thread-safe metrics sink shared by batcher and server threads.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    pub p50_us: u128,
    pub p90_us: u128,
    pub p99_us: u128,
    pub mean_us: f64,
    pub mean_occupancy: f64,
    pub throughput_rps: f64,
}

impl Metrics {
    pub fn record_batch(&self, latencies_us: &[u128], occupancy: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
        g.latencies_us.extend_from_slice(latencies_us);
        g.requests += latencies_us.len() as u64;
        g.batches += 1;
        g.occupancy_sum += occupancy as u64;
    }

    pub fn record_reject(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u128 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() as f64 * p) as usize).min(lat.len() - 1)]
            }
        };
        let elapsed = g.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            rejected: g.rejected,
            p50_us: pct(0.50),
            p90_us: pct(0.90),
            p99_us: pct(0.99),
            mean_us: if lat.is_empty() {
                0.0
            } else {
                lat.iter().sum::<u128>() as f64 / lat.len() as f64
            },
            mean_occupancy: if g.batches == 0 {
                0.0
            } else {
                g.occupancy_sum as f64 / g.batches as f64
            },
            throughput_rps: if elapsed > 0.0 { g.requests as f64 / elapsed } else { 0.0 },
        }
    }
}

impl Snapshot {
    pub fn print(&self, label: &str) {
        println!(
            "{label}: {} reqs in {} batches (occ {:.2}), rejected {} | latency p50 {:.2} ms p90 {:.2} ms p99 {:.2} ms mean {:.2} ms | {:.1} req/s",
            self.requests,
            self.batches,
            self.mean_occupancy,
            self.rejected,
            self.p50_us as f64 / 1e3,
            self.p90_us as f64 / 1e3,
            self.p99_us as f64 / 1e3,
            self.mean_us / 1e3,
            self.throughput_rps,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let m = Metrics::default();
        let lats: Vec<u128> = (1..=100).collect();
        m.record_batch(&lats, 8);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 1);
        assert_eq!(s.p50_us, 51);
        assert_eq!(s.p99_us, 100);
        assert!((s.mean_us - 50.5).abs() < 1e-9);
    }

    #[test]
    fn occupancy_and_rejections() {
        let m = Metrics::default();
        m.record_batch(&[10, 10], 2);
        m.record_batch(&[10, 10, 10, 10], 4);
        m.record_reject();
        let s = m.snapshot();
        assert_eq!(s.rejected, 1);
        assert!((s.mean_occupancy - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50_us, 0);
    }
}
