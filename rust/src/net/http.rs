//! Pure incremental HTTP/1.1 request parser — no sockets, no I/O.
//!
//! The connection loop (`net::server`) feeds raw bytes in with
//! [`HttpReader::feed`] and pulls complete requests out with
//! [`HttpReader::next_request`]; everything between those two calls is
//! deterministic buffer manipulation, so malformed-input hardening and
//! framing edge cases (split feeds, pipelined keep-alive requests,
//! chunked bodies truncated mid-chunk) are unit-tested here without a
//! listener. Limits are enforced as the bytes arrive, not after: a head
//! that exceeds [`Limits::max_head_bytes`] errors before a terminator
//! ever shows up, so an attacker cannot buffer unbounded memory by
//! simply never finishing a request.
//!
//! Supported framing: `Content-Length` bodies, `Transfer-Encoding:
//! chunked` bodies (extensions ignored, trailers skipped), and
//! body-less requests. Both HTTP/1.1 (keep-alive default) and HTTP/1.0
//! (close default) request lines are accepted; anything else is a
//! [`ParseError::UnsupportedVersion`].

/// Bounds the parser enforces while a request is still arriving.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Longest accepted request line (method + target + version).
    pub max_request_line: usize,
    /// Total head bytes (request line + headers + blank line).
    pub max_head_bytes: usize,
    /// Maximum number of header fields.
    pub max_headers: usize,
    /// Largest accepted body, whatever the framing.
    pub max_body_bytes: usize,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            max_request_line: 8 * 1024,
            max_head_bytes: 32 * 1024,
            max_headers: 64,
            max_body_bytes: 4 * 1024 * 1024,
        }
    }
}

/// Why a request could not be parsed. Carries its own HTTP status and
/// stable machine-readable code so the connection loop can answer
/// before closing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParseError {
    /// Request line is not `METHOD SP target SP HTTP/x.y`.
    BadRequestLine,
    /// Not an HTTP/1.0 or HTTP/1.1 request.
    UnsupportedVersion,
    /// A header line has no colon, an empty name, or malformed bytes.
    BadHeader,
    /// Head grew past [`Limits::max_head_bytes`] (or the request line
    /// past [`Limits::max_request_line`]) without completing.
    HeadTooLarge,
    /// More than [`Limits::max_headers`] header fields.
    TooManyHeaders,
    /// `Content-Length` missing a parseable value, or repeated with
    /// disagreeing values.
    BadContentLength,
    /// Declared or accumulated body larger than [`Limits::max_body_bytes`].
    BodyTooLarge,
    /// A chunk-size line is not valid hex (or is oversized).
    BadChunk,
    /// A `Transfer-Encoding` other than `chunked`.
    UnsupportedTransferEncoding,
}

impl ParseError {
    /// HTTP status the connection loop answers with before closing.
    pub fn status(self) -> u16 {
        match self {
            ParseError::BadRequestLine
            | ParseError::BadHeader
            | ParseError::BadContentLength
            | ParseError::BadChunk => 400,
            ParseError::HeadTooLarge | ParseError::TooManyHeaders => 431,
            ParseError::BodyTooLarge => 413,
            ParseError::UnsupportedTransferEncoding => 501,
            ParseError::UnsupportedVersion => 505,
        }
    }

    /// Stable machine-readable code for the error body.
    pub fn code(self) -> &'static str {
        match self {
            ParseError::BadRequestLine => "bad_request_line",
            ParseError::UnsupportedVersion => "unsupported_version",
            ParseError::BadHeader => "bad_header",
            ParseError::HeadTooLarge => "head_too_large",
            ParseError::TooManyHeaders => "too_many_headers",
            ParseError::BadContentLength => "bad_content_length",
            ParseError::BodyTooLarge => "body_too_large",
            ParseError::BadChunk => "bad_chunk",
            ParseError::UnsupportedTransferEncoding => "unsupported_transfer_encoding",
        }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.code())
    }
}

/// One fully parsed request. Header names are lower-cased at parse time
/// (field names are case-insensitive); values keep their bytes minus
/// surrounding whitespace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpRequest {
    pub method: String,
    /// Origin-form target as sent (path + optional `?query`).
    pub target: String,
    /// `true` for HTTP/1.1, `false` for HTTP/1.0.
    pub http11: bool,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First value of a header, by lower-case name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    /// Target path with any `?query` stripped.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// Connection persistence per the version defaults and the
    /// `Connection` header (`close` / `keep-alive` override).
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(str::to_ascii_lowercase) {
            Some(v) if v.contains("close") => false,
            Some(v) if v.contains("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// Body-framing state while a request head has been parsed but its body
/// is still arriving.
#[derive(Debug)]
enum BodyState {
    /// `Content-Length` framing: `need` bytes remain.
    Fixed { need: usize },
    /// Chunked framing: waiting for the next `SIZE\r\n` line.
    ChunkSize,
    /// Chunked framing: `need` data bytes remain in the current chunk
    /// (followed by CRLF).
    ChunkData { need: usize },
    /// Chunked framing: skipping trailer lines until the blank line.
    ChunkTrailer,
}

/// Incremental parser for a stream of pipelined requests on one
/// connection. `feed` appends raw bytes; `next_request` consumes at
/// most one complete request from the front of the buffer. Leftover
/// bytes stay buffered for the next call, which is exactly what
/// keep-alive pipelining needs.
#[derive(Debug)]
pub struct HttpReader {
    limits: Limits,
    buf: Vec<u8>,
    /// Head parsed, body still arriving.
    pending: Option<(HttpRequest, BodyState)>,
    /// Poisoned after the first error: HTTP/1.1 framing cannot recover
    /// from a desynchronized stream, so the connection must close.
    dead: Option<ParseError>,
}

impl HttpReader {
    pub fn new(limits: Limits) -> HttpReader {
        HttpReader { limits, buf: Vec::new(), pending: None, dead: None }
    }

    /// Append raw bytes read off the socket.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed into a request.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// `true` if a request is mid-parse (head seen, body incomplete) —
    /// an EOF here means the peer truncated a request.
    pub fn mid_request(&self) -> bool {
        self.pending.is_some()
    }

    /// Try to produce the next complete request. `Ok(None)` means "need
    /// more bytes"; an error poisons the reader (framing is lost) and
    /// repeats on every later call.
    pub fn next_request(&mut self) -> Result<Option<HttpRequest>, ParseError> {
        if let Some(e) = self.dead {
            return Err(e);
        }
        match self.advance() {
            Ok(out) => Ok(out),
            Err(e) => {
                self.dead = Some(e);
                Err(e)
            }
        }
    }

    fn advance(&mut self) -> Result<Option<HttpRequest>, ParseError> {
        if self.pending.is_none() {
            match self.take_head()? {
                None => return Ok(None),
                Some(pending) => self.pending = Some(pending),
            }
        }
        // Drive body framing until complete or out of bytes.
        loop {
            let (req, state) = self.pending.as_mut().expect("pending head");
            match state {
                BodyState::Fixed { need } => {
                    if *need == 0 || self.buf.len() >= *need {
                        let n = *need;
                        req.body.extend_from_slice(&self.buf[..n]);
                        self.buf.drain(..n);
                        let (req, _) = self.pending.take().expect("pending head");
                        return Ok(Some(req));
                    }
                    return Ok(None);
                }
                BodyState::ChunkSize => {
                    let Some(line_end) = find_crlf(&self.buf) else {
                        // A size line is tiny; anything longer is garbage.
                        if self.buf.len() > 128 {
                            return Err(ParseError::BadChunk);
                        }
                        return Ok(None);
                    };
                    let line = &self.buf[..line_end];
                    let size = parse_chunk_size(line)?;
                    self.buf.drain(..line_end + 2);
                    if size == 0 {
                        *state = BodyState::ChunkTrailer;
                    } else {
                        if req.body.len() + size > self.limits.max_body_bytes {
                            return Err(ParseError::BodyTooLarge);
                        }
                        *state = BodyState::ChunkData { need: size };
                    }
                }
                BodyState::ChunkData { need } => {
                    // chunk data plus its trailing CRLF
                    if self.buf.len() < *need + 2 {
                        return Ok(None);
                    }
                    let n = *need;
                    if &self.buf[n..n + 2] != b"\r\n" {
                        return Err(ParseError::BadChunk);
                    }
                    req.body.extend_from_slice(&self.buf[..n]);
                    self.buf.drain(..n + 2);
                    *state = BodyState::ChunkSize;
                }
                BodyState::ChunkTrailer => {
                    let Some(line_end) = find_crlf(&self.buf) else {
                        if self.buf.len() > self.limits.max_head_bytes {
                            return Err(ParseError::HeadTooLarge);
                        }
                        return Ok(None);
                    };
                    let blank = line_end == 0;
                    self.buf.drain(..line_end + 2);
                    if blank {
                        let (req, _) = self.pending.take().expect("pending head");
                        return Ok(Some(req));
                    }
                }
            }
        }
    }

    /// Parse a complete head off the front of the buffer, if one has
    /// arrived. Enforces head-size limits even while incomplete.
    fn take_head(&mut self) -> Result<Option<(HttpRequest, BodyState)>, ParseError> {
        let Some(head_end) = find_double_crlf(&self.buf) else {
            if self.buf.len() > self.limits.max_head_bytes {
                return Err(ParseError::HeadTooLarge);
            }
            // cheap early reject: a request line that never terminates
            if find_crlf(&self.buf).is_none() && self.buf.len() > self.limits.max_request_line {
                return Err(ParseError::HeadTooLarge);
            }
            return Ok(None);
        };
        if head_end + 4 > self.limits.max_head_bytes {
            return Err(ParseError::HeadTooLarge);
        }
        let head: Vec<u8> = self.buf.drain(..head_end + 4).collect();
        let head = &head[..head_end];
        let mut lines = split_crlf(head);
        let request_line = lines.next().ok_or(ParseError::BadRequestLine)?;
        if request_line.len() > self.limits.max_request_line {
            return Err(ParseError::HeadTooLarge);
        }
        let (method, target, http11) = parse_request_line(request_line)?;
        let mut headers = Vec::new();
        for line in lines {
            if headers.len() >= self.limits.max_headers {
                return Err(ParseError::TooManyHeaders);
            }
            headers.push(parse_header_line(line)?);
        }
        let req = HttpRequest { method, target, http11, headers, body: Vec::new() };
        let state = self.body_state_for(&req)?;
        Ok(Some((req, state)))
    }

    /// Decide body framing from the parsed head.
    fn body_state_for(&self, req: &HttpRequest) -> Result<BodyState, ParseError> {
        if let Some(te) = req.header("transfer-encoding") {
            if !te.eq_ignore_ascii_case("chunked") {
                return Err(ParseError::UnsupportedTransferEncoding);
            }
            return Ok(BodyState::ChunkSize);
        }
        let mut need = 0usize;
        let mut seen = false;
        for (n, v) in &req.headers {
            if n == "content-length" {
                let parsed: usize =
                    v.trim().parse().map_err(|_| ParseError::BadContentLength)?;
                if seen && parsed != need {
                    return Err(ParseError::BadContentLength);
                }
                need = parsed;
                seen = true;
            }
        }
        if need > self.limits.max_body_bytes {
            return Err(ParseError::BodyTooLarge);
        }
        Ok(BodyState::Fixed { need })
    }
}

fn find_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\r\n")
}

fn find_double_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Iterate the CRLF-separated lines of a head (terminator not included).
fn split_crlf(head: &[u8]) -> impl Iterator<Item = &[u8]> {
    head.split(|&b| b == b'\n').map(|l| l.strip_suffix(b"\r").unwrap_or(l))
}

fn parse_request_line(line: &[u8]) -> Result<(String, String, bool), ParseError> {
    let line = std::str::from_utf8(line).map_err(|_| ParseError::BadRequestLine)?;
    let mut parts = line.split(' ');
    let (Some(method), Some(target), Some(version), None) =
        (parts.next(), parts.next(), parts.next(), parts.next())
    else {
        return Err(ParseError::BadRequestLine);
    };
    if method.is_empty()
        || method.len() > 16
        || !method.bytes().all(|b| b.is_ascii_uppercase())
    {
        return Err(ParseError::BadRequestLine);
    }
    if !target.starts_with('/') {
        return Err(ParseError::BadRequestLine);
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(ParseError::UnsupportedVersion),
    };
    Ok((method.to_string(), target.to_string(), http11))
}

fn parse_header_line(line: &[u8]) -> Result<(String, String), ParseError> {
    let line = std::str::from_utf8(line).map_err(|_| ParseError::BadHeader)?;
    let (name, value) = line.split_once(':').ok_or(ParseError::BadHeader)?;
    // field names are tokens: no whitespace, no empties
    if name.is_empty() || name.contains(|c: char| c.is_ascii_whitespace()) {
        return Err(ParseError::BadHeader);
    }
    Ok((name.to_ascii_lowercase(), value.trim().to_string()))
}

fn parse_chunk_size(line: &[u8]) -> Result<usize, ParseError> {
    let line = std::str::from_utf8(line).map_err(|_| ParseError::BadChunk)?;
    // chunk extensions (";ext=val") are legal; ignore them
    let hex = line.split(';').next().unwrap_or("").trim();
    if hex.is_empty() || hex.len() > 8 {
        return Err(ParseError::BadChunk);
    }
    usize::from_str_radix(hex, 16).map_err(|_| ParseError::BadChunk)
}

// ---------------------------------------------------------------------------
// Response serialization (the write half the connection loop uses)
// ---------------------------------------------------------------------------

/// Reason phrase for the statuses this server emits.
pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Serialize a complete fixed-length response.
pub fn response_bytes(
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> Vec<u8> {
    let mut out = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        reason_phrase(status),
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    )
    .into_bytes();
    out.extend_from_slice(body);
    out
}

/// Serialize the head of a chunked streaming response.
pub fn chunked_head_bytes(status: u16, content_type: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: keep-alive\r\n\r\n",
        status,
        reason_phrase(status),
        content_type,
    )
    .into_bytes()
}

/// Serialize one chunk (hex size line + data + CRLF).
pub fn chunk_bytes(data: &[u8]) -> Vec<u8> {
    let mut out = format!("{:x}\r\n", data.len()).into_bytes();
    out.extend_from_slice(data);
    out.extend_from_slice(b"\r\n");
    out
}

/// The terminating zero chunk.
pub fn final_chunk_bytes() -> &'static [u8] {
    b"0\r\n\r\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reader() -> HttpReader {
        HttpReader::new(Limits::default())
    }

    fn parse_one(bytes: &[u8]) -> Result<Option<HttpRequest>, ParseError> {
        let mut r = reader();
        r.feed(bytes);
        r.next_request()
    }

    #[test]
    fn parses_a_simple_get() {
        let req = parse_one(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap()
            .expect("complete");
        assert_eq!(req.method, "GET");
        assert_eq!(req.path(), "/healthz");
        assert!(req.http11);
        assert!(req.keep_alive());
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_content_length_body_across_split_feeds() {
        let wire = b"POST /v1/sessions HTTP/1.1\r\nContent-Length: 11\r\n\r\nhello world";
        // feed byte-by-byte: every prefix must be NeedMore, never an error
        let mut r = reader();
        for (i, b) in wire.iter().enumerate() {
            r.feed(&[*b]);
            let out = r.next_request().expect("no error on any prefix");
            if i + 1 < wire.len() {
                assert!(out.is_none(), "premature completion at byte {i}");
            } else {
                let req = out.expect("complete at the last byte");
                assert_eq!(req.body, b"hello world");
            }
        }
    }

    #[test]
    fn parses_chunked_body_with_extensions_and_trailers() {
        let wire = b"POST /v1/generate HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                     4;ext=1\r\nWiki\r\n5\r\npedia\r\n0\r\nTrailer: v\r\n\r\n";
        let req = parse_one(wire).unwrap().expect("complete");
        assert_eq!(req.body, b"Wikipedia");
    }

    #[test]
    fn truncated_chunked_body_stays_incomplete_not_errored() {
        // head + one full chunk + a declared-but-unsent second chunk:
        // the reader must report "need more", so the connection loop can
        // distinguish a slow client from a malformed one; EOF here is a
        // truncation the loop detects via mid_request().
        let mut r = reader();
        r.feed(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n4\r\nWiki\r\nA\r\npart");
        assert_eq!(r.next_request().unwrap(), None);
        assert!(r.mid_request(), "EOF now would be a truncated request");
    }

    #[test]
    fn chunk_data_missing_crlf_is_an_error() {
        let wire = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n4\r\nWikiXX";
        let mut r = reader();
        r.feed(wire);
        assert_eq!(r.next_request(), Err(ParseError::BadChunk));
        // poisoned: the error repeats instead of resynchronizing
        assert_eq!(r.next_request(), Err(ParseError::BadChunk));
    }

    #[test]
    fn bad_chunk_size_lines_error() {
        for bad in ["zz", "", " ;x", "123456789AB"] {
            let wire =
                format!("POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n{bad}\r\n");
            let mut r = reader();
            r.feed(wire.as_bytes());
            assert_eq!(r.next_request(), Err(ParseError::BadChunk), "size line {bad:?}");
        }
    }

    #[test]
    fn malformed_request_lines_are_rejected() {
        let cases: &[(&[u8], ParseError)] = &[
            (b"GET\r\n\r\n" as &[u8], ParseError::BadRequestLine),
            (b"GET /\r\n\r\n", ParseError::BadRequestLine),
            (b"GET / HTTP/1.1 extra\r\n\r\n", ParseError::BadRequestLine),
            (b"get / HTTP/1.1\r\n\r\n", ParseError::BadRequestLine),
            (b"GET nopath HTTP/1.1\r\n\r\n", ParseError::BadRequestLine),
            (b"GET / HTTP/2.0\r\n\r\n", ParseError::UnsupportedVersion),
            (b"GET / SPDY/3\r\n\r\n", ParseError::UnsupportedVersion),
            (b"\xff\xfe / HTTP/1.1\r\n\r\n", ParseError::BadRequestLine),
        ];
        for (wire, want) in cases {
            assert_eq!(parse_one(wire).unwrap_err(), *want, "wire {wire:?}");
        }
    }

    #[test]
    fn malformed_headers_are_rejected() {
        for wire in [
            b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n".as_slice(),
            b"GET / HTTP/1.1\r\n: empty-name\r\n\r\n",
            b"GET / HTTP/1.1\r\nbad name: v\r\n\r\n",
        ] {
            assert_eq!(parse_one(wire).unwrap_err(), ParseError::BadHeader);
        }
    }

    #[test]
    fn oversized_heads_error_before_the_terminator_arrives() {
        let limits = Limits { max_head_bytes: 256, ..Limits::default() };
        let mut r = HttpReader::new(limits);
        r.feed(b"GET / HTTP/1.1\r\n");
        // an endless stream of headers, never a blank line
        for i in 0.. {
            r.feed(format!("x-h{i}: {}\r\n", "v".repeat(32)).as_bytes());
            match r.next_request() {
                Ok(None) => assert!(r.buffered() <= 512, "buffer must stay bounded"),
                Err(e) => {
                    assert_eq!(e, ParseError::HeadTooLarge);
                    assert_eq!(e.status(), 431);
                    return;
                }
                Ok(Some(_)) => panic!("no complete request was ever sent"),
            }
        }
    }

    #[test]
    fn unterminated_request_line_errors_at_the_line_limit() {
        let limits = Limits { max_request_line: 64, ..Limits::default() };
        let mut r = HttpReader::new(limits);
        r.feed(&[b'A'; 100]);
        assert_eq!(r.next_request(), Err(ParseError::HeadTooLarge));
    }

    #[test]
    fn too_many_headers_is_rejected() {
        let limits = Limits { max_headers: 4, ..Limits::default() };
        let mut wire = String::from("GET / HTTP/1.1\r\n");
        for i in 0..6 {
            wire.push_str(&format!("h{i}: v\r\n"));
        }
        wire.push_str("\r\n");
        let mut r = HttpReader::new(limits);
        r.feed(wire.as_bytes());
        assert_eq!(r.next_request(), Err(ParseError::TooManyHeaders));
    }

    #[test]
    fn content_length_limits_and_conflicts() {
        let limits = Limits { max_body_bytes: 8, ..Limits::default() };
        let mut r = HttpReader::new(limits);
        r.feed(b"POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\n");
        assert_eq!(r.next_request(), Err(ParseError::BodyTooLarge));
        assert_eq!(ParseError::BodyTooLarge.status(), 413);

        assert_eq!(
            parse_one(b"POST / HTTP/1.1\r\nContent-Length: abc\r\n\r\n").unwrap_err(),
            ParseError::BadContentLength
        );
        assert_eq!(
            parse_one(b"POST / HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 4\r\n\r\n")
                .unwrap_err(),
            ParseError::BadContentLength
        );
        // repeated but agreeing lengths are tolerated
        let req =
            parse_one(b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nok")
                .unwrap()
                .expect("complete");
        assert_eq!(req.body, b"ok");
    }

    #[test]
    fn unsupported_transfer_encoding_is_501() {
        let e = parse_one(b"POST / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n").unwrap_err();
        assert_eq!(e, ParseError::UnsupportedTransferEncoding);
        assert_eq!(e.status(), 501);
    }

    #[test]
    fn pipelined_keep_alive_requests_parse_in_order() {
        let mut r = reader();
        r.feed(
            b"POST /a HTTP/1.1\r\nContent-Length: 3\r\n\r\nonePOST /b HTTP/1.1\r\n\
              Content-Length: 3\r\n\r\ntwoGET /c HTTP/1.1\r\n\r\n",
        );
        let a = r.next_request().unwrap().expect("first");
        assert_eq!((a.path(), a.body.as_slice()), ("/a", b"one".as_slice()));
        let b = r.next_request().unwrap().expect("second");
        assert_eq!((b.path(), b.body.as_slice()), ("/b", b"two".as_slice()));
        let c = r.next_request().unwrap().expect("third");
        assert_eq!(c.path(), "/c");
        assert_eq!(r.next_request().unwrap(), None, "stream drained");
        assert_eq!(r.buffered(), 0);
    }

    #[test]
    fn connection_header_overrides_version_default() {
        let req =
            parse_one(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive());
        let req = parse_one(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive(), "1.0 defaults to close");
        let req = parse_one(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(req.keep_alive());
    }

    #[test]
    fn response_serialization_round_trips_framing() {
        let bytes = response_bytes(200, "application/json", b"{}", true);
        let s = String::from_utf8(bytes).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.ends_with("\r\n\r\n{}"));

        let head = String::from_utf8(chunked_head_bytes(200, "application/x-ndjson")).unwrap();
        assert!(head.contains("Transfer-Encoding: chunked\r\n"));

        assert_eq!(chunk_bytes(b"abc"), b"3\r\nabc\r\n");
        assert_eq!(chunk_bytes(&[b'x'; 16]).starts_with(b"10\r\n"), true);
        assert_eq!(final_chunk_bytes(), b"0\r\n\r\n");
    }

    #[test]
    fn query_strings_are_stripped_by_path() {
        let req = parse_one(b"GET /v1/metrics?pretty=1 HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.path(), "/v1/metrics");
        assert_eq!(req.target, "/v1/metrics?pretty=1");
    }
}
