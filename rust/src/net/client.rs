//! Minimal blocking HTTP/1.1 client — just enough protocol to drive the
//! front-end from integration tests, the net stress bench, and the
//! serving example without external dependencies. Supports keep-alive
//! reuse, `Content-Length` bodies, and incremental chunked reads (one
//! chunk per call) so a caller can timestamp the first streamed token
//! the way a real client observes TTFT.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Parsed response head; the body is read separately (fully via
/// [`HttpClient::read_body`] or chunk-at-a-time via
/// [`HttpClient::next_chunk`]).
#[derive(Clone, Debug)]
pub struct ResponseHead {
    pub status: u16,
    /// lowercased names, order preserved
    pub headers: Vec<(String, String)>,
}

impl ResponseHead {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    pub fn chunked(&self) -> bool {
        self.header("transfer-encoding").is_some_and(|v| v.eq_ignore_ascii_case("chunked"))
    }

    pub fn content_length(&self) -> Option<usize> {
        self.header("content-length").and_then(|v| v.trim().parse().ok())
    }
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// One TCP connection to the front-end (keep-alive: issue several
/// requests back to back on the same `HttpClient`).
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(HttpClient { stream, buf: Vec::new() })
    }

    pub fn set_timeouts(&self, read: Option<Duration>, write: Option<Duration>) -> io::Result<()> {
        self.stream.set_read_timeout(read)?;
        self.stream.set_write_timeout(write)
    }

    /// Send one request. A `body` implies `Content-Length` framing.
    pub fn send(&mut self, method: &str, path: &str, body: Option<&[u8]>) -> io::Result<()> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nHost: had\r\n");
        if let Some(b) = body {
            head.push_str(&format!("Content-Type: application/json\r\nContent-Length: {}\r\n", b.len()));
        }
        head.push_str("\r\n");
        self.stream.write_all(head.as_bytes())?;
        if let Some(b) = body {
            self.stream.write_all(b)?;
        }
        self.stream.flush()
    }

    /// Send one request with a chunked body (each slice becomes one
    /// chunk) — exercises the server's chunked request decoding over a
    /// real socket.
    pub fn send_chunked(&mut self, method: &str, path: &str, chunks: &[&[u8]]) -> io::Result<()> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: had\r\nContent-Type: application/json\r\nTransfer-Encoding: chunked\r\n\r\n"
        );
        self.stream.write_all(head.as_bytes())?;
        for c in chunks {
            self.stream.write_all(format!("{:x}\r\n", c.len()).as_bytes())?;
            self.stream.write_all(c)?;
            self.stream.write_all(b"\r\n")?;
        }
        self.stream.write_all(b"0\r\n\r\n")?;
        self.stream.flush()
    }

    fn fill(&mut self) -> io::Result<()> {
        let mut tmp = [0u8; 4096];
        let n = self.stream.read(&mut tmp)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "peer closed mid-response"));
        }
        self.buf.extend_from_slice(&tmp[..n]);
        Ok(())
    }

    /// Pop one CRLF-terminated line off the buffer (filling as needed).
    fn read_line(&mut self) -> io::Result<String> {
        loop {
            if let Some(pos) = self.buf.windows(2).position(|w| w == b"\r\n") {
                let line = String::from_utf8(self.buf[..pos].to_vec())
                    .map_err(|_| bad("non-UTF-8 header line"))?;
                self.buf.drain(..pos + 2);
                return Ok(line);
            }
            self.fill()?;
        }
    }

    /// Pop exactly `n` bytes off the buffer (filling as needed).
    fn read_exact_buf(&mut self, n: usize) -> io::Result<Vec<u8>> {
        while self.buf.len() < n {
            self.fill()?;
        }
        let out = self.buf[..n].to_vec();
        self.buf.drain(..n);
        Ok(out)
    }

    /// Read a response's status line and headers; body left unread.
    pub fn read_head(&mut self) -> io::Result<ResponseHead> {
        let status_line = self.read_line()?;
        let mut parts = status_line.splitn(3, ' ');
        let version = parts.next().unwrap_or("");
        if !version.starts_with("HTTP/1.") {
            return Err(bad("not an HTTP response"));
        }
        let status: u16 =
            parts.next().and_then(|s| s.parse().ok()).ok_or_else(|| bad("bad status code"))?;
        let mut headers = Vec::new();
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            let (name, value) = line.split_once(':').ok_or_else(|| bad("bad header"))?;
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
        Ok(ResponseHead { status, headers })
    }

    /// Read one chunk of a chunked body. `Ok(None)` after the final
    /// (zero-length) chunk and its trailer section.
    pub fn next_chunk(&mut self) -> io::Result<Option<Vec<u8>>> {
        let size_line = self.read_line()?;
        let size_hex = size_line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_hex, 16).map_err(|_| bad("bad chunk size"))?;
        if size == 0 {
            loop {
                if self.read_line()?.is_empty() {
                    return Ok(None);
                }
            }
        }
        let data = self.read_exact_buf(size + 2)?; // data + CRLF
        if &data[size..] != b"\r\n" {
            return Err(bad("chunk missing CRLF"));
        }
        Ok(Some(data[..size].to_vec()))
    }

    /// Drain a response body completely (either framing).
    pub fn read_body(&mut self, head: &ResponseHead) -> io::Result<Vec<u8>> {
        if head.chunked() {
            let mut out = Vec::new();
            while let Some(chunk) = self.next_chunk()? {
                out.extend_from_slice(&chunk);
            }
            Ok(out)
        } else {
            let n = head.content_length().unwrap_or(0);
            self.read_exact_buf(n)
        }
    }
}

/// One-shot convenience: connect, send, read the full response.
pub fn roundtrip(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> io::Result<(u16, Vec<u8>)> {
    let mut c = HttpClient::connect(addr)?;
    c.set_timeouts(Some(Duration::from_secs(10)), Some(Duration::from_secs(10)))?;
    c.send(method, path, body)?;
    let head = c.read_head()?;
    let body = c.read_body(&head)?;
    Ok((head.status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Serve one canned response on a throwaway listener, return its addr.
    fn canned(resp: &'static [u8]) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            if let Ok((mut s, _)) = listener.accept() {
                let mut sink = [0u8; 1024];
                s.read(&mut sink).ok(); // consume the request head
                s.write_all(resp).ok();
            }
        });
        addr
    }

    #[test]
    fn parses_content_length_response() {
        let addr = canned(b"HTTP/1.1 200 OK\r\nContent-Type: text/plain\r\nContent-Length: 5\r\n\r\nhello");
        let (status, body) = roundtrip(addr, "GET", "/x", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"hello");
    }

    #[test]
    fn reads_chunked_response_chunk_by_chunk() {
        let addr = canned(
            b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n3\r\nfoo\r\n4\r\nbars\r\n0\r\n\r\n",
        );
        let mut c = HttpClient::connect(addr).unwrap();
        c.set_timeouts(Some(Duration::from_secs(5)), Some(Duration::from_secs(5))).unwrap();
        c.send("GET", "/stream", None).unwrap();
        let head = c.read_head().unwrap();
        assert!(head.chunked());
        assert_eq!(c.next_chunk().unwrap().as_deref(), Some(b"foo".as_slice()));
        assert_eq!(c.next_chunk().unwrap().as_deref(), Some(b"bars".as_slice()));
        assert_eq!(c.next_chunk().unwrap(), None);
    }

    #[test]
    fn rejects_garbage_status_line() {
        let addr = canned(b"garbage\r\n\r\n");
        let err = roundtrip(addr, "GET", "/", None).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }
}
