//! HTTP/1.1 network front-end: the serving coordinator over real
//! sockets, with zero new dependencies (std `TcpListener` + the
//! existing `util::threadpool` substrate — DESIGN.md §Substrates).
//!
//! Three layers, separated so each is testable on its own:
//!
//! * [`http`] — pure incremental request parser + response serializers.
//!   No I/O; malformed-input hardening and framing edge cases are unit
//!   tests over byte slices.
//! * [`api`] — the JSON wire contract: request bodies, response/event
//!   serialization, and the `RejectReason` → HTTP status + stable wire
//!   code mapping shared with `scripts/validate_net.py`.
//! * [`server`] — the connection loop: accept thread + worker pool,
//!   per-connection read/write deadlines, keep-alive pipelining,
//!   chunked per-token streaming for `/v1/generate`, and the
//!   `net_accept` / `net_write` chaos sites.
//!
//! [`client`] is a minimal blocking HTTP client used by the socket
//! tests, the `net_stress` bench, and the `serve_http` example — it
//! reads chunked responses one chunk at a time, so client-observed TTFT
//! is measurable without external tooling.

pub mod api;
pub mod client;
pub mod http;
pub mod server;

pub use client::{roundtrip, HttpClient, ResponseHead};
pub use http::{HttpReader, HttpRequest, Limits, ParseError};
pub use server::{NetConfig, NetServer};
