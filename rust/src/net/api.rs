//! The JSON wire surface of the HTTP front-end: request-body parsing,
//! response/event serialization, and the mapping from the engine's
//! typed refusals (`RejectReason`, `ParseError`) to HTTP statuses and
//! stable machine-readable error codes.
//!
//! Everything here is pure data transformation over `util::json::Json`
//! (no sockets), so the wire contract is unit-testable next to the
//! types it serializes. Codes come from `RejectReason::wire_code` /
//! `StopReason::wire_code` — clients must key off those, never off the
//! human-readable `message` strings.

use crate::coordinator::{RejectReason, Response};
use crate::generate::{GenerateRequest, SamplingParams, StreamEvent};
use crate::util::json::Json;

/// HTTP status a rejected admission maps to. Refusals the client can
/// retry later (backpressure) are 429; server lifecycle and stall
/// refusals are 503; the rest are caller errors on this deployment.
pub fn reject_status(r: RejectReason) -> u16 {
    match r {
        RejectReason::TooLong => 413,
        RejectReason::QueueFull => 429,
        RejectReason::ShuttingDown | RejectReason::Timeout => 503,
        RejectReason::EmptyGeneration => 400,
        RejectReason::Unsupported => 501,
    }
}

/// `{"error": {"code": ..., "message": ...}}` — the uniform error body.
pub fn error_body(code: &str, message: &str) -> Vec<u8> {
    Json::obj(vec![(
        "error",
        Json::obj(vec![("code", Json::str(code)), ("message", Json::str(message))]),
    )])
    .to_string()
    .into_bytes()
}

/// Error body for a rejected admission.
pub fn reject_body(r: RejectReason) -> Vec<u8> {
    error_body(r.wire_code(), &r.to_string())
}

/// One streamed event as a JSONL line (no trailing newline; the caller
/// frames it). `done` carries the stop reason's wire code.
pub fn event_json(event: &StreamEvent) -> Json {
    match event {
        StreamEvent::Token { index, token } => Json::obj(vec![
            ("event", Json::str("token")),
            ("index", Json::num(*index as f64)),
            ("token", Json::num(*token as f64)),
        ]),
        StreamEvent::Done { reason, generated, ttft_us } => Json::obj(vec![
            ("event", Json::str("done")),
            ("reason", Json::str(reason.wire_code())),
            ("generated", Json::num(*generated as f64)),
            ("ttft_us", Json::num(*ttft_us as f64)),
        ]),
    }
}

/// A classification turn's response body (`POST /v1/sessions`).
pub fn response_json(session: u64, resp: &Response) -> Json {
    Json::obj(vec![
        ("session", Json::num(session as f64)),
        ("pred", Json::num(resp.pred)),
        ("logits", Json::arr(resp.logits.iter().map(|&v| Json::num(v as f64)))),
        ("bucket", Json::str(resp.bucket.clone())),
        ("latency_us", Json::num(resp.latency_us as f64)),
        ("batch_occupancy", Json::num(resp.batch_occupancy as f64)),
        ("cached_tokens", Json::num(resp.cached_tokens as f64)),
    ])
}

fn parse_json(body: &[u8]) -> Result<Json, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    Json::parse(text).map_err(|e| format!("body is not valid JSON: {e:?}"))
}

fn get_u64(obj: &Json, key: &str) -> Result<u64, String> {
    let v = obj.get(key).ok_or_else(|| format!("missing field '{key}'"))?;
    let f = v.as_f64().ok_or_else(|| format!("field '{key}' must be a number"))?;
    if f < 0.0 || f.fract() != 0.0 {
        return Err(format!("field '{key}' must be a non-negative integer"));
    }
    Ok(f as u64)
}

fn get_tokens(obj: &Json, key: &str) -> Result<Vec<i32>, String> {
    let v = obj.get(key).ok_or_else(|| format!("missing field '{key}'"))?;
    let arr = v.as_arr().ok_or_else(|| format!("field '{key}' must be an array"))?;
    arr.iter()
        .map(|t| {
            let f = t.as_f64().ok_or_else(|| format!("'{key}' holds a non-number"))?;
            if f.fract() != 0.0 || f < i32::MIN as f64 || f > i32::MAX as f64 {
                return Err(format!("'{key}' holds a non-i32 value"));
            }
            Ok(f as i32)
        })
        .collect()
}

/// Parse a `POST /v1/sessions` body: `{"session": id, "tokens": [...]}`.
pub fn parse_sessions_body(body: &[u8]) -> Result<(u64, Vec<i32>), String> {
    let obj = parse_json(body)?;
    Ok((get_u64(&obj, "session")?, get_tokens(&obj, "tokens")?))
}

/// Parse a `POST /v1/generate` body:
/// `{"session", "prompt", "max_new_tokens"[, "stop_tokens",
/// "temperature", "top_k", "top_p", "seed"]}`. Sampling fields default
/// to greedy decoding, which keeps seeded runs reproducible end to end.
pub fn parse_generate_body(body: &[u8]) -> Result<(u64, GenerateRequest), String> {
    let obj = parse_json(body)?;
    let session = get_u64(&obj, "session")?;
    let prompt = get_tokens(&obj, "prompt")?;
    let max_new_tokens = get_u64(&obj, "max_new_tokens")? as usize;
    let stop_tokens =
        if obj.get("stop_tokens").is_some() { get_tokens(&obj, "stop_tokens")? } else { Vec::new() };
    let mut sampling = SamplingParams::greedy();
    if let Some(t) = obj.get("temperature") {
        let t = t.as_f64().ok_or("field 'temperature' must be a number")?;
        if !(t >= 0.0) || !t.is_finite() {
            return Err("field 'temperature' must be finite and >= 0".to_string());
        }
        sampling.temperature = t as f32;
    }
    if obj.get("top_k").is_some() {
        sampling.top_k = get_u64(&obj, "top_k")? as usize;
    }
    if let Some(p) = obj.get("top_p") {
        let p = p.as_f64().ok_or("field 'top_p' must be a number")?;
        if !(p > 0.0 && p <= 1.0) {
            return Err("field 'top_p' must be in (0, 1]".to_string());
        }
        sampling.top_p = p as f32;
    }
    if obj.get("seed").is_some() {
        sampling.seed = get_u64(&obj, "seed")?;
    }
    Ok((session, GenerateRequest { prompt, max_new_tokens, stop_tokens, sampling }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generate::StopReason;

    #[test]
    fn reject_statuses_cover_every_variant() {
        for r in RejectReason::ALL {
            let status = reject_status(r);
            assert!(
                matches!(status, 400 | 413 | 429 | 501 | 503),
                "{r:?} mapped to unexpected status {status}"
            );
        }
        assert_eq!(reject_status(RejectReason::QueueFull), 429);
        assert_eq!(reject_status(RejectReason::ShuttingDown), 503);
    }

    #[test]
    fn error_bodies_carry_the_wire_code() {
        let body = String::from_utf8(reject_body(RejectReason::QueueFull)).unwrap();
        let parsed = Json::parse(&body).unwrap();
        assert_eq!(parsed.at(&["error", "code"]).and_then(Json::as_str), Some("queue_full"));
        assert!(parsed.at(&["error", "message"]).is_some());
    }

    #[test]
    fn event_serialization_round_trips_through_wire_codes() {
        let tok = event_json(&StreamEvent::Token { index: 3, token: 17 }).to_string();
        let parsed = Json::parse(&tok).unwrap();
        assert_eq!(parsed.get("event").and_then(Json::as_str), Some("token"));
        assert_eq!(parsed.get("index").and_then(Json::as_usize), Some(3));
        assert_eq!(parsed.get("token").and_then(Json::as_f64), Some(17.0));

        let done = event_json(&StreamEvent::Done {
            reason: StopReason::MaxTokens,
            generated: 8,
            ttft_us: 1234,
        })
        .to_string();
        let parsed = Json::parse(&done).unwrap();
        let code = parsed.get("reason").and_then(Json::as_str).unwrap();
        assert_eq!(StopReason::from_wire_code(code), Some(StopReason::MaxTokens));
        assert_eq!(parsed.get("generated").and_then(Json::as_usize), Some(8));
    }

    #[test]
    fn sessions_body_parses_and_validates() {
        let (sid, toks) =
            parse_sessions_body(br#"{"session": 7, "tokens": [1, 2, 3]}"#).unwrap();
        assert_eq!(sid, 7);
        assert_eq!(toks, vec![1, 2, 3]);
        assert!(parse_sessions_body(b"not json").is_err());
        assert!(parse_sessions_body(br#"{"tokens": [1]}"#).is_err(), "missing session");
        assert!(parse_sessions_body(br#"{"session": 1}"#).is_err(), "missing tokens");
        assert!(parse_sessions_body(br#"{"session": 1.5, "tokens": []}"#).is_err());
        assert!(parse_sessions_body(br#"{"session": 1, "tokens": [1.5]}"#).is_err());
        assert!(parse_sessions_body(&[0xff, 0xfe]).is_err(), "non-UTF-8 body");
    }

    #[test]
    fn generate_body_defaults_to_greedy() {
        let (sid, req) = parse_generate_body(
            br#"{"session": 2, "prompt": [4, 5], "max_new_tokens": 6}"#,
        )
        .unwrap();
        assert_eq!(sid, 2);
        assert_eq!(req.prompt, vec![4, 5]);
        assert_eq!(req.max_new_tokens, 6);
        assert!(req.stop_tokens.is_empty());
        assert_eq!(req.sampling, SamplingParams::greedy());
    }

    #[test]
    fn generate_body_accepts_sampling_knobs_and_rejects_bad_ones() {
        let (_, req) = parse_generate_body(
            br#"{"session": 1, "prompt": [1], "max_new_tokens": 4,
                 "stop_tokens": [0], "temperature": 0.75, "top_k": 3,
                 "top_p": 0.9, "seed": 42}"#,
        )
        .unwrap();
        assert_eq!(req.stop_tokens, vec![0]);
        assert!((req.sampling.temperature - 0.75).abs() < 1e-6);
        assert_eq!(req.sampling.top_k, 3);
        assert!((req.sampling.top_p - 0.9).abs() < 1e-6);
        assert_eq!(req.sampling.seed, 42);

        for bad in [
            br#"{"session": 1, "prompt": [1], "max_new_tokens": 4, "top_p": 0}"#.as_slice(),
            br#"{"session": 1, "prompt": [1], "max_new_tokens": 4, "top_p": 1.5}"#,
            br#"{"session": 1, "prompt": [1], "max_new_tokens": 4, "temperature": -1}"#,
            br#"{"session": 1, "prompt": [1]}"#,
        ] {
            assert!(parse_generate_body(bad).is_err(), "accepted {:?}", bad);
        }
    }

    #[test]
    fn response_json_carries_the_turn_fields() {
        let resp = Response {
            id: 1,
            pred: 2,
            logits: vec![0.5, -1.0],
            bucket: "demo".into(),
            latency_us: 1000,
            batch_occupancy: 3,
            cached_tokens: 4,
            kernel_us: 0,
            decode_us: 0,
        };
        let j = response_json(9, &resp);
        assert_eq!(j.get("session").and_then(Json::as_usize), Some(9));
        assert_eq!(j.get("pred").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("logits").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        assert_eq!(j.get("cached_tokens").and_then(Json::as_usize), Some(4));
    }
}
