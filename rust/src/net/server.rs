//! The socket half of the HTTP front-end: a dependency-free listener
//! that exposes a running `coordinator::Server` over real TCP.
//!
//! One accept thread hands connections to a fixed
//! `util::threadpool::ThreadPool`; each handler runs a read loop around
//! the pure parser ([`super::http::HttpReader`]) so pipelined
//! keep-alive requests drain in order, and routes:
//!
//! * `GET  /healthz`            — liveness probe
//! * `GET  /v1/metrics`         — metric registry snapshot as JSON
//! * `POST /v1/sessions`        — one classification turn (`submit_session`)
//! * `POST /v1/generate`        — streamed generation: one `StreamEvent`
//!   per `Transfer-Encoding: chunked` chunk (JSONL), written and flushed
//!   as each token is sampled so client-observed TTFT is honest
//! * `DELETE /v1/sessions/{id}` — end a session, releasing its KV pages
//!
//! Backpressure crosses the socket boundary in both directions: typed
//! admission refusals become HTTP statuses with machine-readable codes
//! (`api::reject_status` / wire codes), and a slow reader trips the
//! per-write deadline, which drops the stream's receiver — exactly the
//! bounded-channel disconnect the scheduler already handles
//! (`StopReason::Disconnected`), so a stalled client can never wedge a
//! decode tick. Seeded chaos reaches the socket layer through two fault
//! sites: `net_accept` (drop a just-accepted connection) and
//! `net_write` (stall a chunk write).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::Server;
use crate::obs::{self, SpanId};
use crate::util::fault::{self, Fault, FaultPlan, SITE_NET_ACCEPT, SITE_NET_WRITE};
use crate::util::threadpool::ThreadPool;

use super::api;
use super::http::{self, HttpReader, HttpRequest, Limits};

/// Tuning knobs of the listener. Defaults suit tests and the demo
/// deployment; production would raise `workers`.
#[derive(Clone)]
pub struct NetConfig {
    /// Connection-handler threads (also the keep-alive concurrency cap:
    /// a connection holds its worker for its whole lifetime).
    pub workers: usize,
    /// Per-read deadline; an idle keep-alive connection is closed when
    /// it fires.
    pub read_timeout: Duration,
    /// Per-write deadline; a streaming client that stays unwritable
    /// this long is treated as disconnected.
    pub write_timeout: Duration,
    /// Parser bounds (head/body size, header count).
    pub limits: Limits,
    /// Socket-layer fault plan; defaults to the process-wide `HAD_FAULT`
    /// plan so the net sites join the same seeded sweep as the engine
    /// sites.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            workers: 4,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(10),
            limits: Limits::default(),
            faults: fault::from_env(),
        }
    }
}

/// A bound, serving listener. Dropping it stops the accept loop and
/// joins every in-flight connection handler (the pool drop is the
/// barrier), then the wrapped `Server`'s own drop runs its graceful
/// drain — so teardown order matches a real shutdown: stop accepting,
/// finish connections, drain streams.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `server` in the background.
    pub fn bind<A: ToSocketAddrs>(
        server: Arc<Server>,
        addr: A,
        cfg: NetConfig,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("had-net-accept".into())
            .spawn(move || accept_loop(listener, server, cfg, stop2))?;
        Ok(NetServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and wait for in-flight connections to finish.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, server: Arc<Server>, cfg: NetConfig, stop: Arc<AtomicBool>) {
    // The pool lives on the accept thread's stack: when the loop breaks,
    // dropping it joins every in-flight handler before the thread exits.
    let pool = ThreadPool::new(cfg.workers.max(1));
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((conn, _peer)) => {
                // Seeded chaos: drop the connection on the floor before a
                // byte is served (clients observe EOF and must retry).
                if matches!(fault::fire(&cfg.faults, SITE_NET_ACCEPT), Some(Fault::Deny)) {
                    drop(conn);
                    continue;
                }
                let server = Arc::clone(&server);
                let cfg = cfg.clone();
                pool.submit(move || handle_conn(conn, &server, &cfg));
            }
            // Non-blocking accept: poll the stop flag between attempts.
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

fn handle_conn(mut conn: TcpStream, server: &Server, cfg: &NetConfig) {
    server.metrics.record_net_connection();
    let mut conn_span = obs::root_span("net_conn");
    conn.set_nodelay(true).ok(); // per-token chunks must not sit in Nagle
    if conn.set_read_timeout(Some(cfg.read_timeout)).is_err()
        || conn.set_write_timeout(Some(cfg.write_timeout)).is_err()
    {
        return;
    }
    let mut reader = HttpReader::new(cfg.limits);
    let mut served = 0u64;
    let mut buf = [0u8; 8 * 1024];
    'conn: loop {
        // Drain everything already buffered (pipelined keep-alive).
        loop {
            match reader.next_request() {
                Ok(Some(req)) => {
                    served += 1;
                    if !dispatch(&mut conn, server, cfg, &req) {
                        break 'conn;
                    }
                }
                Ok(None) => break,
                Err(e) => {
                    // Framing is lost: answer once, then close.
                    server.metrics.record_net_parse_error();
                    let body = api::error_body(e.code(), &e.to_string());
                    let resp = http::response_bytes(e.status(), "application/json", &body, false);
                    conn.write_all(&resp).ok();
                    break 'conn;
                }
            }
        }
        match conn.read(&mut buf) {
            Ok(0) => break, // clean EOF
            Ok(n) => reader.feed(&buf[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            // WouldBlock / TimedOut (read deadline on an idle
            // connection) and hard errors all end the connection.
            Err(_) => break,
        }
    }
    conn_span.set_payload(served);
}

/// Serve one parsed request. Returns whether the connection may be
/// kept alive.
fn dispatch(conn: &mut TcpStream, server: &Server, cfg: &NetConfig, req: &HttpRequest) -> bool {
    server.metrics.record_net_request();
    let trace = obs::sample_request();
    let start = Instant::now();
    let keep_req = req.keep_alive();
    let (status, keep) = match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => {
            let body = br#"{"status":"ok"}"#;
            write_simple(conn, 200, body, keep_req)
        }
        ("GET", "/v1/metrics") => {
            let body = server.metrics.registry().snapshot_json().to_string().into_bytes();
            write_simple(conn, 200, &body, keep_req)
        }
        ("POST", "/v1/sessions") => match api::parse_sessions_body(&req.body) {
            Ok((sid, tokens)) => match server.submit_session(sid, tokens) {
                Ok(rx) => match rx.recv() {
                    Ok(resp) => {
                        let body = api::response_json(sid, &resp).to_string().into_bytes();
                        write_simple(conn, 200, &body, keep_req)
                    }
                    // Reply sender dropped: the batch failed server-side.
                    Err(_) => write_error(conn, 500, "internal", "reply channel closed"),
                },
                Err(r) => write_reject(conn, r, keep_req),
            },
            Err(msg) => write_error(conn, 400, "bad_request", &msg),
        },
        ("POST", "/v1/generate") => match api::parse_generate_body(&req.body) {
            Ok((sid, greq)) => match server.submit_generate(sid, greq) {
                Ok(rx) => stream_events(conn, server, cfg, rx, keep_req),
                Err(r) => write_reject(conn, r, keep_req),
            },
            Err(msg) => write_error(conn, 400, "bad_request", &msg),
        },
        ("DELETE", path) if path.starts_with("/v1/sessions/") => {
            match path["/v1/sessions/".len()..].parse::<u64>() {
                Ok(sid) => {
                    server.sessions().lock().unwrap_or_else(|e| e.into_inner()).end_session(sid);
                    let body = format!(r#"{{"session":{sid},"ended":true}}"#).into_bytes();
                    write_simple(conn, 200, &body, keep_req)
                }
                Err(_) => write_error(conn, 400, "bad_request", "session id is not a u64"),
            }
        }
        _ => write_error(conn, 404, "not_found", "unknown method or path"),
    };
    obs::record_as(trace, SpanId::NONE, "net_request", start, start.elapsed().as_micros() as u64, status as u64);
    keep
}

/// Write a fixed-length response; returns `(status, keep_alive)` where
/// `keep_alive` is false if the write failed.
fn write_simple(conn: &mut TcpStream, status: u16, body: &[u8], keep: bool) -> (u16, bool) {
    let resp = http::response_bytes(status, "application/json", body, keep);
    let ok = conn.write_all(&resp).is_ok();
    (status, keep && ok)
}

fn write_error(conn: &mut TcpStream, status: u16, code: &str, msg: &str) -> (u16, bool) {
    // Error responses always close: the conversation went wrong, so give
    // the client an unambiguous framing boundary to restart from.
    let (status, _) = write_simple(conn, status, &api::error_body(code, msg), false);
    (status, false)
}

fn write_reject(
    conn: &mut TcpStream,
    r: crate::coordinator::RejectReason,
    keep: bool,
) -> (u16, bool) {
    // A shutdown refusal also closes the connection (nothing further
    // will be admitted); other rejections are per-request and retryable
    // on the same connection.
    let keep = keep && r != crate::coordinator::RejectReason::ShuttingDown;
    write_simple(conn, api::reject_status(r), &api::reject_body(r), keep)
}

/// Deliver a generation stream as chunked JSONL, one event per chunk,
/// flushed per token. A write that hits the deadline (or any write
/// error) drops `rx`, which the scheduler observes as a client
/// disconnect on its next send — the net layer's slow-reader story is
/// the coordinator's bounded-channel story, surfaced one hop earlier.
fn stream_events(
    conn: &mut TcpStream,
    server: &Server,
    cfg: &NetConfig,
    rx: std::sync::mpsc::Receiver<crate::generate::StreamEvent>,
    keep: bool,
) -> (u16, bool) {
    if conn.write_all(&http::chunked_head_bytes(200, "application/jsonl")).is_err() {
        return (200, false);
    }
    for event in rx.iter() {
        // Seeded chaos: stall this chunk write (the deterministic stand-in
        // for a congested socket), surfaced in the slow-write counter.
        if let Some(Fault::Delay(d)) = fault::fire(&cfg.faults, SITE_NET_WRITE) {
            server.metrics.record_net_slow_write();
            std::thread::sleep(d);
        }
        let mut line = api::event_json(&event).to_string().into_bytes();
        line.push(b'\n');
        if conn.write_all(&http::chunk_bytes(&line)).is_err() || conn.flush().is_err() {
            server.metrics.record_net_slow_write();
            return (200, false); // dropping rx disconnects the stream
        }
    }
    // Sender dropped after `Done`: the stream retired; finish the framing.
    let ok = conn.write_all(http::final_chunk_bytes()).is_ok();
    (200, keep && ok)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchPolicy, Bucket, Router};
    use crate::generate::{generate, GenLimits, GenerateRequest, StreamEvent};
    use crate::kvcache::KvCacheConfig;
    use crate::net::client::{roundtrip, HttpClient};
    use crate::runtime::{ConfigEntry, ModelCfg};
    use crate::serve::{token_config_entry, HadBackend, ServeModel};
    use crate::util::json::Json;

    const MODEL_SEED: u64 = 0xBEEF;

    fn tiny_model_cfg() -> ConfigEntry {
        token_config_entry(
            "net_srv",
            ModelCfg {
                n_layers: 2, d_model: 32, n_heads: 2, d_ff: 64, n_ctx: 32,
                n_classes: 3, vocab: 24, input_dim: 0, n_top: 8, block_q: 16,
            },
        )
    }

    fn tiny_backend(kv: &KvCacheConfig) -> HadBackend {
        HadBackend::new(ServeModel::random(&tiny_model_cfg(), MODEL_SEED).unwrap(), kv)
    }

    fn kv_cfg() -> KvCacheConfig {
        KvCacheConfig { page_tokens: 4, ..Default::default() }
    }

    fn coordinator() -> Arc<Server> {
        let kv = kv_cfg();
        let router = Router::new(vec![Bucket { config: "net_srv".into(), n_ctx: 32, batch: 4 }]);
        let policy =
            BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() };
        Arc::new(Server::builder(tiny_backend(&kv), router, policy).kv(kv).start().unwrap())
    }

    fn test_net_cfg() -> NetConfig {
        NetConfig {
            workers: 2,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            limits: Limits::default(),
            faults: None, // never inherit HAD_FAULT from the test env
        }
    }

    fn serve() -> (NetServer, SocketAddr) {
        let net = NetServer::bind(coordinator(), "127.0.0.1:0", test_net_cfg()).unwrap();
        let addr = net.local_addr();
        (net, addr)
    }

    #[test]
    fn healthz_and_metrics_respond() {
        let (_net, addr) = serve();
        let (status, body) = roundtrip(addr, "GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, br#"{"status":"ok"}"#);

        let (status, body) = roundtrip(addr, "GET", "/v1/metrics", None).unwrap();
        assert_eq!(status, 200);
        let parsed = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        // the net counters observed their own connections
        let conns = parsed.at(&["counters", "net_connections"]).and_then(Json::as_f64);
        assert!(conns.is_some_and(|c| c >= 1.0), "metrics body: {parsed}");
    }

    #[test]
    fn sessions_turn_over_the_socket_returns_the_turn_fields() {
        let (_net, addr) = serve();
        let (status, body) =
            roundtrip(addr, "POST", "/v1/sessions", Some(br#"{"session":1,"tokens":[1,2,3,4]}"#))
                .unwrap();
        assert_eq!(status, 200, "body: {}", String::from_utf8_lossy(&body));
        let parsed = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(parsed.get("session").and_then(Json::as_usize), Some(1));
        assert!(parsed.get("pred").and_then(Json::as_f64).is_some());
        assert_eq!(parsed.get("logits").and_then(Json::as_arr).map(<[Json]>::len), Some(3));

        // second turn reuses the resident pages
        let (status, body) =
            roundtrip(addr, "POST", "/v1/sessions", Some(br#"{"session":1,"tokens":[5,6]}"#))
                .unwrap();
        assert_eq!(status, 200);
        let parsed = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(parsed.get("cached_tokens").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn chunked_request_body_is_decoded_over_the_socket() {
        let (_net, addr) = serve();
        let mut c = HttpClient::connect(addr).unwrap();
        c.set_timeouts(Some(Duration::from_secs(5)), Some(Duration::from_secs(5))).unwrap();
        c.send_chunked(
            "POST",
            "/v1/sessions",
            &[br#"{"session":3,"#.as_slice(), br#""tokens":[1,2,3]}"#.as_slice()],
        )
        .unwrap();
        let head = c.read_head().unwrap();
        let body = c.read_body(&head).unwrap();
        assert_eq!(head.status, 200, "body: {}", String::from_utf8_lossy(&body));
    }

    /// The acceptance property: a seeded generate over the socket streams
    /// exactly the token events the direct engine loop produces — the
    /// HTTP layer adds framing, never content.
    #[test]
    fn streamed_generate_is_byte_identical_to_the_direct_engine() {
        let (_net, addr) = serve();
        let prompt = vec![1i32, 2, 3, 4];
        let max_new = 6usize;

        // direct-engine oracle over an identical model (same cfg + seed)
        let backend = tiny_backend(&kv_cfg());
        let req = GenerateRequest::greedy(prompt.clone(), max_new);
        let mut want_lines: Vec<String> = Vec::new();
        let out = generate(&backend, &mut backend.fresh_kv(), &[], &req, &GenLimits::unbounded(), |index, token| {
            want_lines.push(api::event_json(&StreamEvent::Token { index, token }).to_string());
        });

        // socket side: one chunk per event, JSONL framed
        let mut c = HttpClient::connect(addr).unwrap();
        c.set_timeouts(Some(Duration::from_secs(10)), Some(Duration::from_secs(10))).unwrap();
        let body = format!(
            r#"{{"session":7,"prompt":[1,2,3,4],"max_new_tokens":{max_new}}}"#
        );
        c.send("POST", "/v1/generate", Some(body.as_bytes())).unwrap();
        let head = c.read_head().unwrap();
        assert_eq!(head.status, 200);
        assert!(head.chunked(), "streaming must be chunked");
        let mut got_lines: Vec<String> = Vec::new();
        while let Some(chunk) = c.next_chunk().unwrap() {
            let text = String::from_utf8(chunk).unwrap();
            assert!(text.ends_with('\n'), "each chunk is one JSONL line");
            got_lines.push(text.trim_end().to_string());
        }

        let done_line = got_lines.pop().expect("stream ends with a done event");
        assert_eq!(got_lines, want_lines, "token events must be byte-identical");
        let done = Json::parse(&done_line).unwrap();
        assert_eq!(done.get("event").and_then(Json::as_str), Some("done"));
        assert_eq!(
            done.get("reason").and_then(Json::as_str),
            Some(out.reason.wire_code()),
            "stop reason must match the direct engine"
        );
        assert_eq!(done.get("generated").and_then(Json::as_usize), Some(out.tokens.len()));
        assert!(done.get("ttft_us").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn rejects_map_to_stable_statuses_and_codes() {
        let (_net, addr) = serve();
        // empty context: EmptyGeneration -> 400 + wire code
        let (status, body) = roundtrip(
            addr,
            "POST",
            "/v1/generate",
            Some(br#"{"session":9,"prompt":[],"max_new_tokens":4}"#),
        )
        .unwrap();
        assert_eq!(status, 400);
        let parsed = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(parsed.at(&["error", "code"]).and_then(Json::as_str), Some("empty_generation"));

        // sequence longer than every bucket: TooLong -> 413
        let toks: Vec<String> = (0..64).map(|i| i.to_string()).collect();
        let body = format!(r#"{{"session":10,"tokens":[{}]}}"#, toks.join(","));
        let (status, body) =
            roundtrip(addr, "POST", "/v1/sessions", Some(body.as_bytes())).unwrap();
        assert_eq!(status, 413, "body: {}", String::from_utf8_lossy(&body));
        let parsed = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(parsed.at(&["error", "code"]).and_then(Json::as_str), Some("too_long"));
    }

    #[test]
    fn unknown_route_and_malformed_body_answer_cleanly() {
        let (_net, addr) = serve();
        let (status, _) = roundtrip(addr, "GET", "/nope", None).unwrap();
        assert_eq!(status, 404);
        let (status, body) =
            roundtrip(addr, "POST", "/v1/sessions", Some(b"this is not json")).unwrap();
        assert_eq!(status, 400);
        let parsed = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(parsed.at(&["error", "code"]).and_then(Json::as_str), Some("bad_request"));
    }

    #[test]
    fn malformed_request_line_gets_400_and_the_parse_error_counter() {
        let server = coordinator();
        let net = NetServer::bind(Arc::clone(&server), "127.0.0.1:0", test_net_cfg()).unwrap();
        let mut conn = TcpStream::connect(net.local_addr()).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        conn.write_all(b"garbage\r\n\r\n").unwrap();
        let mut resp = Vec::new();
        conn.read_to_end(&mut resp).unwrap(); // server answers then closes
        let text = String::from_utf8_lossy(&resp);
        assert!(text.starts_with("HTTP/1.1 400"), "got: {text}");
        assert!(text.contains("bad_request_line"), "got: {text}");
        assert_eq!(server.metrics.snapshot().net_parse_errors, 1);
    }

    #[test]
    fn keep_alive_serves_sequential_requests_on_one_connection() {
        let (_net, addr) = serve();
        let mut c = HttpClient::connect(addr).unwrap();
        c.set_timeouts(Some(Duration::from_secs(5)), Some(Duration::from_secs(5))).unwrap();
        for turn in 0..3 {
            c.send("GET", "/healthz", None).unwrap();
            let head = c.read_head().unwrap();
            let body = c.read_body(&head).unwrap();
            assert_eq!(head.status, 200, "turn {turn}");
            assert_eq!(body, br#"{"status":"ok"}"#);
        }
    }

    #[test]
    fn delete_ends_the_session_and_releases_its_pages() {
        let server = coordinator();
        let net = NetServer::bind(Arc::clone(&server), "127.0.0.1:0", test_net_cfg()).unwrap();
        let addr = net.local_addr();
        let (status, _) =
            roundtrip(addr, "POST", "/v1/sessions", Some(br#"{"session":5,"tokens":[1,2,3,4]}"#))
                .unwrap();
        assert_eq!(status, 200);
        assert!(server.sessions().lock().unwrap().pool().bytes() > 0);
        let (status, body) = roundtrip(addr, "DELETE", "/v1/sessions/5", None).unwrap();
        assert_eq!(status, 200);
        let parsed = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(parsed.get("ended").and_then(Json::as_bool), Some(true));
        assert_eq!(server.sessions().lock().unwrap().pool().bytes(), 0);

        let (status, _) = roundtrip(addr, "DELETE", "/v1/sessions/notanid", None).unwrap();
        assert_eq!(status, 400);
    }

    #[test]
    fn net_accept_fault_drops_connections_before_a_byte_is_served() {
        let mut cfg = test_net_cfg();
        cfg.faults = Some(Arc::new(FaultPlan::parse("net_accept,seed=1").unwrap()));
        let net = NetServer::bind(coordinator(), "127.0.0.1:0", cfg).unwrap();
        // always-on accept fault: every request dies without a response
        let err = roundtrip(net.local_addr(), "GET", "/healthz", None);
        assert!(err.is_err(), "connection must be dropped, got {err:?}");
    }
}
