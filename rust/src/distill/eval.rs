//! Evaluation: run a fwd artifact over batches, compute the GLUE-style
//! metrics the paper's tables report (accuracy, Matthews corr, Pearson r).

use anyhow::{Context, Result};

use crate::data::Batch;
use crate::model::Checkpoint;
use crate::runtime::{ConfigEntry, HostTensor, Runtime};
use crate::tensor::ops::argmax;

/// Predictions + labels for one eval pass.
#[derive(Clone, Debug, Default)]
pub struct EvalResult {
    pub preds: Vec<i32>,
    pub labels: Vec<i32>,
}

impl EvalResult {
    pub fn accuracy(&self) -> f32 {
        if self.preds.is_empty() {
            return 0.0;
        }
        let hits = self.preds.iter().zip(&self.labels).filter(|(p, y)| p == y).count();
        hits as f32 / self.preds.len() as f32
    }

    /// Matthews correlation coefficient, binary (CoLA's metric).
    pub fn matthews(&self) -> f32 {
        let (mut tp, mut tn, mut fp, mut fnn) = (0f64, 0f64, 0f64, 0f64);
        for (&p, &y) in self.preds.iter().zip(&self.labels) {
            match (p != 0, y != 0) {
                (true, true) => tp += 1.0,
                (false, false) => tn += 1.0,
                (true, false) => fp += 1.0,
                (false, true) => fnn += 1.0,
            }
        }
        let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
        if denom == 0.0 {
            0.0
        } else {
            (((tp * tn) - (fp * fnn)) / denom) as f32
        }
    }

    /// Pearson correlation of predicted vs true ordinal labels (STS-B's
    /// metric applied to the bucketed analog).
    pub fn pearson(&self) -> f32 {
        let n = self.preds.len() as f64;
        if n < 2.0 {
            return 0.0;
        }
        let (xs, ys): (Vec<f64>, Vec<f64>) = self
            .preds
            .iter()
            .zip(&self.labels)
            .map(|(&p, &y)| (p as f64, y as f64))
            .unzip();
        let mx = xs.iter().sum::<f64>() / n;
        let my = ys.iter().sum::<f64>() / n;
        let mut cov = 0.0;
        let mut vx = 0.0;
        let mut vy = 0.0;
        for (x, y) in xs.iter().zip(&ys) {
            cov += (x - mx) * (y - my);
            vx += (x - mx) * (x - mx);
            vy += (y - my) * (y - my);
        }
        if vx == 0.0 || vy == 0.0 {
            0.0
        } else {
            (cov / (vx * vy).sqrt()) as f32
        }
    }

    /// Metric dispatch by name ("accuracy" | "matthews" | "pearson"),
    /// scaled to percentage points like the paper's tables.
    pub fn metric(&self, name: &str) -> f32 {
        100.0
            * match name {
                "matthews" => self.matthews(),
                "pearson" => self.pearson(),
                _ => self.accuracy(),
            }
    }
}

/// Evaluate `ckpt` with a forward artifact over the given batches.
/// `n_top` is the runtime sparsity parameter (ignored by dense variants).
pub fn evaluate(
    rt: &Runtime,
    cfg: &ConfigEntry,
    fwd_artifact: &str,
    ckpt: &Checkpoint,
    batches: &[Batch],
    n_top: f32,
) -> Result<EvalResult> {
    let exe = rt.load(&format!("{}__{}", cfg.name, fwd_artifact))?;
    let sq = HostTensor::vec_f32(ckpt.sigma_q.clone());
    let sk = HostTensor::vec_f32(ckpt.sigma_k.clone());
    let mut result = EvalResult::default();
    for batch in batches {
        let mut inputs: Vec<HostTensor> = ckpt.params.tensors.clone();
        inputs.push(batch.x.clone());
        inputs.push(sq.clone());
        inputs.push(sk.clone());
        inputs.push(HostTensor::scalar_f32(n_top));
        let out = exe.run(&inputs).context("fwd")?;
        let logits = out[0].as_f32()?;
        let n_classes = cfg.model.n_classes;
        for (b, &y) in batch.labels.iter().enumerate() {
            let row = &logits[b * n_classes..(b + 1) * n_classes];
            result.preds.push(argmax(row) as i32);
            result.labels.push(y);
        }
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn er(preds: Vec<i32>, labels: Vec<i32>) -> EvalResult {
        EvalResult { preds, labels }
    }

    #[test]
    fn accuracy_basic() {
        assert_eq!(er(vec![1, 0, 1], vec![1, 1, 1]).accuracy(), 2.0 / 3.0);
        assert_eq!(er(vec![], vec![]).accuracy(), 0.0);
    }

    #[test]
    fn matthews_perfect_and_inverted() {
        assert!((er(vec![0, 1, 0, 1], vec![0, 1, 0, 1]).matthews() - 1.0).abs() < 1e-6);
        assert!((er(vec![1, 0, 1, 0], vec![0, 1, 0, 1]).matthews() + 1.0).abs() < 1e-6);
        // degenerate single-class predictions -> 0
        assert_eq!(er(vec![1, 1, 1, 1], vec![0, 1, 0, 1]).matthews(), 0.0);
    }

    #[test]
    fn pearson_monotone() {
        assert!((er(vec![0, 1, 2, 3], vec![0, 1, 2, 3]).pearson() - 1.0).abs() < 1e-6);
        assert!(er(vec![3, 2, 1, 0], vec![0, 1, 2, 3]).pearson() < -0.99);
        assert_eq!(er(vec![1, 1], vec![0, 1]).pearson(), 0.0);
    }

    #[test]
    fn metric_dispatch_scales_to_percent() {
        let e = er(vec![1, 1, 0, 0], vec![1, 1, 0, 0]);
        assert_eq!(e.metric("accuracy"), 100.0);
        assert_eq!(e.metric("matthews"), 100.0);
    }
}
