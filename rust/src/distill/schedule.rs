//! Stage schedule for Algorithm 1 (paper §3.5-3.9).
//!
//! The paper decays c exponentially by 0.9998/minibatch over tens of
//! thousands of iterations. On this testbed the step budget is supplied
//! per run, so the decay rate is derived from the budget such that the
//! trajectory (c: 5 -> 1 in stage 1, 1 -> 0.05 in stage 2) is preserved
//! exactly; the paper's constants fall out when the paper's step counts
//! are supplied. EXPERIMENTS.md records the budgets used.

/// One of the four distillation stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// scaled tanh, c: 5 -> 1, outer_mult = c, attention loss on
    Tanh1,
    /// tightening tanh, c: 1 -> 0.05, outer_mult = 1, attention loss on
    Tanh2,
    /// STE, attention loss on
    Ste3,
    /// STE, lower LR, attention loss OFF
    Ste4,
}

pub const C_START: f32 = 5.0;
pub const C_MID: f32 = 1.0;
pub const C_END: f32 = 0.05;

/// Per-run step budget for each stage (+ the teacher pre-training budget).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Budget {
    pub teacher: usize,
    pub stage1: usize,
    pub stage2: usize,
    pub stage3: usize,
    pub stage4: usize,
}

impl Budget {
    /// Scale a reference budget by `x` (>= 0), keeping minimums sane.
    pub fn scaled(&self, x: f64) -> Budget {
        let s = |v: usize| ((v as f64 * x).round() as usize).max(1);
        Budget {
            teacher: s(self.teacher),
            stage1: s(self.stage1),
            stage2: s(self.stage2),
            stage3: s(self.stage3),
            stage4: s(self.stage4),
        }
    }

    pub fn total_distill(&self) -> usize {
        self.stage1 + self.stage2 + self.stage3 + self.stage4
    }
}

impl Default for Budget {
    fn default() -> Self {
        // Testbed defaults (single-core CPU, d=64 L=2 models).
        Budget { teacher: 600, stage1: 150, stage2: 150, stage3: 200, stage4: 100 }
    }
}

/// The c / outer_mult / att_w / lr trajectory.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub budget: Budget,
    pub lr: f32,
    /// stage-4 learning rate (paper: 10x lower)
    pub lr_final: f32,
}

impl Schedule {
    pub fn new(budget: Budget, lr: f32) -> Schedule {
        Schedule { budget, lr, lr_final: lr * 0.1 }
    }

    /// Which stage a global distillation step belongs to.
    pub fn stage(&self, step: usize) -> Stage {
        let b = &self.budget;
        if step < b.stage1 {
            Stage::Tanh1
        } else if step < b.stage1 + b.stage2 {
            Stage::Tanh2
        } else if step < b.stage1 + b.stage2 + b.stage3 {
            Stage::Ste3
        } else {
            Stage::Ste4
        }
    }

    /// Exponential-decay value of c at a global step (paper Eq. 13-15
    /// trajectory). Stages 3/4 pin c at C_END (unused by the STE graph).
    pub fn c_at(&self, step: usize) -> f32 {
        let b = &self.budget;
        match self.stage(step) {
            Stage::Tanh1 => {
                let frac = step as f32 / b.stage1.max(1) as f32;
                C_START * (C_MID / C_START).powf(frac)
            }
            Stage::Tanh2 => {
                let frac = (step - b.stage1) as f32 / b.stage2.max(1) as f32;
                C_MID * (C_END / C_MID).powf(frac)
            }
            _ => C_END,
        }
    }

    /// outer_mult: c during stage 1 (Eq. 13), 1 afterwards (Eq. 15+).
    pub fn outer_mult_at(&self, step: usize) -> f32 {
        match self.stage(step) {
            Stage::Tanh1 => self.c_at(step),
            _ => 1.0,
        }
    }

    /// attention-distillation loss weight (Eq. 11; 0 in stage 4).
    pub fn att_w_at(&self, step: usize) -> f32 {
        if self.stage(step) == Stage::Ste4 {
            0.0
        } else {
            1.0
        }
    }

    pub fn lr_at(&self, step: usize) -> f32 {
        if self.stage(step) == Stage::Ste4 {
            self.lr_final
        } else {
            self.lr
        }
    }

    /// Whether the STE artifact (vs the tanh artifact) runs this step.
    pub fn uses_ste(&self, step: usize) -> bool {
        matches!(self.stage(step), Stage::Ste3 | Stage::Ste4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Schedule {
        Schedule::new(Budget { teacher: 0, stage1: 100, stage2: 100, stage3: 50, stage4: 50 }, 1e-4)
    }

    #[test]
    fn stage_boundaries() {
        let s = sched();
        assert_eq!(s.stage(0), Stage::Tanh1);
        assert_eq!(s.stage(99), Stage::Tanh1);
        assert_eq!(s.stage(100), Stage::Tanh2);
        assert_eq!(s.stage(199), Stage::Tanh2);
        assert_eq!(s.stage(200), Stage::Ste3);
        assert_eq!(s.stage(250), Stage::Ste4);
    }

    #[test]
    fn c_trajectory_monotone_and_continuous() {
        let s = sched();
        assert!((s.c_at(0) - C_START).abs() < 1e-5);
        // end of stage 1 ~= C_MID; start of stage 2 == C_MID
        assert!((s.c_at(100) - C_MID).abs() < 0.05);
        let mut prev = s.c_at(0);
        for step in 1..200 {
            let c = s.c_at(step);
            assert!(c <= prev + 1e-6, "c must decay");
            prev = c;
        }
        assert!((s.c_at(199) - C_END).abs() < 0.2);
    }

    #[test]
    fn stage1_outer_mult_tracks_c() {
        let s = sched();
        assert_eq!(s.outer_mult_at(50), s.c_at(50));
        assert_eq!(s.outer_mult_at(150), 1.0);
    }

    #[test]
    fn stage4_drops_attention_loss_and_lr() {
        let s = sched();
        assert_eq!(s.att_w_at(200), 1.0);
        assert_eq!(s.att_w_at(250), 0.0);
        assert!((s.lr_at(250) - 1e-5).abs() < 1e-9);
    }

    #[test]
    fn budget_scaling() {
        let b = Budget::default().scaled(0.1);
        assert!(b.stage1 >= 1 && b.teacher >= 1);
        assert_eq!(Budget::default().scaled(1.0).stage1, Budget::default().stage1);
    }

    #[test]
    fn paper_constants_recovered_at_paper_scale() {
        // With the paper's decay 0.9998/step, c: 5 -> 1 takes
        // ln(0.2)/ln(0.9998) ~= 8047 steps. Supplying that budget must
        // reproduce c(t) = 5 * 0.9998^t within rounding.
        let steps = (f64::ln(0.2) / f64::ln(0.9998)).round() as usize;
        let s = Schedule::new(
            Budget { teacher: 0, stage1: steps, stage2: steps, stage3: 0, stage4: 0 },
            1e-5,
        );
        for &t in &[0usize, 1000, 4000, 8000] {
            let paper_c = 5.0f64 * 0.9998f64.powi(t as i32);
            assert!(
                ((s.c_at(t) as f64) - paper_c).abs() / paper_c < 0.01,
                "step {t}: {} vs {paper_c}",
                s.c_at(t)
            );
        }
    }
}
