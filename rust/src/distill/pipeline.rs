//! The distillation pipeline driver: teacher pre-training, sigma
//! calibration, and the 4-stage student distillation of Algorithm 1 —
//! all executed through the PJRT artifacts; no Python anywhere.

use anyhow::{Context, Result};

use super::schedule::{Schedule, Stage};
use crate::data::Batch;
use crate::log_info;
use crate::model::{Checkpoint, ParamSet, TrainState};
use crate::runtime::{ConfigEntry, HostTensor, Runtime};
use crate::util::rng::Rng;

/// The six Table-1/2 columns (and the Figure-3 subject).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// full-precision teacher = the Baseline row
    Baseline,
    /// HAD (ours): full Algorithm-1 pipeline
    Had,
    /// "w/ SAB": HAD pipeline + BiViT softmax-aware attention binarization
    Sab,
    /// "w/o AD": attention-distillation loss removed throughout
    HadNoAd,
    /// "w/o Tanh": tanh stages replaced by equal-length STE training
    HadNoTanh,
    /// BiT-like full activation binarization baseline
    Bit,
    /// full-precision + top-N only (the Figure-3 subject)
    FpTopn,
}

impl Method {
    pub const TABLE_COLUMNS: [Method; 6] = [
        Method::Baseline,
        Method::Had,
        Method::Bit,
        Method::Sab,
        Method::HadNoAd,
        Method::HadNoTanh,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Method::Baseline => "Baseline",
            Method::Had => "HAD (ours)",
            Method::Sab => "w/ SAB",
            Method::HadNoAd => "w/o AD",
            Method::HadNoTanh => "w/o Tanh",
            Method::Bit => "BiT",
            Method::FpTopn => "FP top-N",
        }
    }

    /// distill artifact family: (tanh artifact, ste artifact)
    fn artifacts(&self) -> Option<(&'static str, &'static str)> {
        match self {
            Method::Baseline => None,
            Method::Had | Method::HadNoAd | Method::HadNoTanh => {
                Some(("distill_had_tanh", "distill_had_ste"))
            }
            Method::Sab => Some(("distill_sab_tanh", "distill_sab_ste")),
            Method::Bit => Some(("distill_bit_ste", "distill_bit_ste")),
            Method::FpTopn => Some(("distill_fptopn", "distill_fptopn")),
        }
    }

    /// eval forward artifact for the distilled student
    pub fn fwd_artifact(&self) -> &'static str {
        match self {
            Method::Baseline => "fwd_standard",
            Method::Had | Method::HadNoAd | Method::HadNoTanh => "fwd_had",
            Method::Sab => "fwd_sab",
            Method::Bit => "fwd_bit",
            Method::FpTopn => "fwd_fptopn",
        }
    }

    /// "w/o Tanh" replaces stages 1-2 with an equal number of STE steps.
    fn skip_tanh(&self) -> bool {
        matches!(self, Method::HadNoTanh | Method::Bit)
    }

    fn att_loss_enabled(&self) -> bool {
        !matches!(self, Method::HadNoAd)
    }
}

/// Everything produced by one distillation run.
pub struct DistillOutcome {
    pub student: Checkpoint,
    /// (global_step, kl_att, kl_out) trace
    pub loss_trace: Vec<(usize, f32, f32)>,
}

/// Supplies training batches (deterministic in its own rng).
pub type BatchFn<'a> = dyn FnMut(&mut Rng) -> Batch + 'a;

pub struct Pipeline<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: &'rt ConfigEntry,
    pub schedule: Schedule,
    pub teacher_lr: f32,
    /// log every k steps
    pub log_every: usize,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: &'rt ConfigEntry, schedule: Schedule) -> Pipeline<'rt> {
        Pipeline { rt, cfg, schedule, teacher_lr: 2e-3, log_every: 100 }
    }

    fn qual(&self, name: &str) -> String {
        format!("{}__{}", self.cfg.name, name)
    }

    /// Teacher pre-training: cross-entropy on the task, standard attention.
    /// Returns the trained teacher parameters and the final train accuracy.
    pub fn train_teacher(
        &self,
        rng: &mut Rng,
        batches: &mut BatchFn<'_>,
    ) -> Result<(ParamSet, f32)> {
        let exe = self.rt.load(&self.qual("teacher_step"))?;
        let mut state = TrainState::new(self.cfg, rng);
        let mut acc_avg = 0.0f32;
        for step in 0..self.schedule.budget.teacher {
            let batch = batches(rng);
            let mut inputs = state.to_inputs();
            inputs.push(batch.x.clone());
            inputs.push(batch.y.clone());
            inputs.push(HostTensor::scalar_f32(self.teacher_lr));
            let outputs = exe.run(&inputs).context("teacher step")?;
            let (next, aux) = TrainState::from_outputs(self.cfg, outputs)?;
            state = next;
            let loss = aux[0].scalar()?;
            let acc = aux[1].scalar()?;
            acc_avg = 0.95 * acc_avg + 0.05 * acc;
            if step % self.log_every == 0 || step + 1 == self.schedule.budget.teacher {
                log_info!(
                    "[{}] teacher step {step}/{}: loss={loss:.4} acc~{acc_avg:.3}",
                    self.cfg.name,
                    self.schedule.budget.teacher
                );
            }
        }
        Ok((state.params, acc_avg))
    }

    /// Paper §3.4 / Eq. 12: average per-minibatch std over `n_batches`.
    pub fn calibrate_sigma(
        &self,
        teacher: &ParamSet,
        rng: &mut Rng,
        batches: &mut BatchFn<'_>,
        n_batches: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self.rt.load(&self.qual("calib"))?;
        let l = self.cfg.model.n_layers;
        let mut sq = vec![0.0f32; l];
        let mut sk = vec![0.0f32; l];
        for _ in 0..n_batches {
            let batch = batches(rng);
            let mut inputs: Vec<HostTensor> = teacher.tensors.clone();
            inputs.push(batch.x.clone());
            let out = exe.run(&inputs).context("calib step")?;
            for (dst, t) in [(&mut sq, &out[0]), (&mut sk, &out[1])] {
                for (d, &v) in dst.iter_mut().zip(t.as_f32()?) {
                    *d += v / n_batches as f32;
                }
            }
        }
        log_info!("[{}] calibrated sigma_q={sq:?} sigma_k={sk:?}", self.cfg.name);
        Ok((sq, sk))
    }

    /// Algorithm 1 stages 1-4. `n_top` is the runtime sparsity parameter N.
    pub fn distill(
        &self,
        method: Method,
        teacher: &ParamSet,
        sigma_q: &[f32],
        sigma_k: &[f32],
        n_top: f32,
        rng: &mut Rng,
        batches: &mut BatchFn<'_>,
    ) -> Result<DistillOutcome> {
        let (tanh_art, ste_art) = method
            .artifacts()
            .context("Baseline has no distillation run")?;
        let tanh_exe = if method.skip_tanh() {
            self.rt.load(&self.qual(ste_art))?
        } else {
            self.rt.load(&self.qual(tanh_art))?
        };
        let ste_exe = self.rt.load(&self.qual(ste_art))?;

        // Student initialized from teacher weights (Algorithm 1 line 1).
        let mut state = TrainState::from_params(self.cfg, teacher.clone());
        let sq = HostTensor::vec_f32(sigma_q.to_vec());
        let sk = HostTensor::vec_f32(sigma_k.to_vec());

        let total = self.schedule.budget.total_distill();
        let mut trace = Vec::new();
        for step in 0..total {
            let stage = self.schedule.stage(step);
            let use_ste = self.schedule.uses_ste(step) || method.skip_tanh();
            let exe = if use_ste { &ste_exe } else { &tanh_exe };
            let c = self.schedule.c_at(step);
            let outer = self.schedule.outer_mult_at(step);
            let att_w = if method.att_loss_enabled() {
                self.schedule.att_w_at(step)
            } else {
                0.0
            };
            let lr = self.schedule.lr_at(step);

            let batch = batches(rng);
            let mut inputs = state.to_inputs();
            inputs.extend(teacher.tensors.iter().cloned());
            inputs.push(batch.x.clone());
            inputs.push(sq.clone());
            inputs.push(sk.clone());
            inputs.push(HostTensor::scalar_f32(c));
            inputs.push(HostTensor::scalar_f32(outer));
            inputs.push(HostTensor::scalar_f32(att_w));
            inputs.push(HostTensor::scalar_f32(lr));
            inputs.push(HostTensor::scalar_f32(n_top));
            let outputs = exe.run(&inputs).with_context(|| format!("distill step {step}"))?;
            let (next, aux) = TrainState::from_outputs(self.cfg, outputs)?;
            state = next;
            let kl_att = aux[0].scalar()?;
            let kl_out = aux[1].scalar()?;
            trace.push((step, kl_att, kl_out));
            if step % self.log_every == 0 || step + 1 == total {
                log_info!(
                    "[{}/{}] {stage:?} step {step}/{total}: c={c:.3} kl_att={kl_att:.4} kl_out={kl_out:.4}",
                    self.cfg.name,
                    method.label()
                );
            }
            debug_assert!(
                stage != Stage::Ste4 || att_w == 0.0 || !method.att_loss_enabled()
            );
        }

        Ok(DistillOutcome {
            student: Checkpoint {
                config: self.cfg.name.clone(),
                step: state.t,
                sigma_q: sigma_q.to_vec(),
                sigma_k: sigma_k.to_vec(),
                params: state.params,
            },
            loss_trace: trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_artifact_mapping() {
        assert!(Method::Baseline.artifacts().is_none());
        assert_eq!(Method::Had.artifacts().unwrap().0, "distill_had_tanh");
        assert_eq!(Method::Bit.artifacts().unwrap().1, "distill_bit_ste");
        assert_eq!(Method::Sab.fwd_artifact(), "fwd_sab");
        assert!(Method::HadNoTanh.skip_tanh());
        assert!(!Method::Had.skip_tanh());
        assert!(!Method::HadNoAd.att_loss_enabled());
    }

    #[test]
    fn table_columns_order_matches_paper() {
        let labels: Vec<&str> = Method::TABLE_COLUMNS.iter().map(|m| m.label()).collect();
        assert_eq!(
            labels,
            ["Baseline", "HAD (ours)", "BiT", "w/ SAB", "w/o AD", "w/o Tanh"]
        );
    }
}
