//! The distillation framework (paper Algorithm 1) driven from Rust:
//! schedule (c decay, stage transitions), pipeline (teacher training,
//! sigma calibration, 4-stage student distillation), and evaluation with
//! the paper's metrics.

pub mod eval;
pub mod pipeline;
pub mod schedule;

pub use eval::{evaluate, EvalResult};
pub use pipeline::{DistillOutcome, Method, Pipeline};
pub use schedule::{Budget, Schedule, Stage};
