//! Procedural shape-classification images: the ImageNet/DeiT analog
//! (Table 2, Figure 3).
//!
//! 32x32 images with 3 channels, drawn procedurally with noise, then
//! patchified into an 8x8 grid of 4x4x3 = 48-dim patches — matching the
//! `vision_*` configs (n_ctx = 65 with the CLS slot, input_dim = 48).

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

pub const IMG: usize = 32;
pub const CH: usize = 3;
pub const PATCH: usize = 4;
pub const GRID: usize = IMG / PATCH; // 8
pub const N_PATCHES: usize = GRID * GRID; // 64
pub const PATCH_DIM: usize = PATCH * PATCH * CH; // 48
pub const N_CLASSES: usize = 8;

pub const CLASS_NAMES: [&str; N_CLASSES] = [
    "square-outline",
    "square-filled",
    "disk",
    "cross",
    "h-stripes",
    "v-stripes",
    "diagonal",
    "checkerboard",
];

/// Render one image (row-major HWC) of the given class with jittered
/// geometry, per-class hue, and additive noise.
pub fn render(class: usize, rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0.0f32; IMG * IMG * CH];
    let cx = 12.0 + 8.0 * rng.next_f32();
    let cy = 12.0 + 8.0 * rng.next_f32();
    let r = 6.0 + 6.0 * rng.next_f32();
    // per-class base color, jittered
    let hue = [
        (0.9, 0.2, 0.2),
        (0.2, 0.9, 0.2),
        (0.2, 0.2, 0.9),
        (0.9, 0.9, 0.2),
        (0.9, 0.2, 0.9),
        (0.2, 0.9, 0.9),
        (0.7, 0.7, 0.7),
        (0.9, 0.5, 0.2),
    ][class];
    let jitter = 0.2 * rng.next_f32();
    let color = [hue.0 + jitter, hue.1 + jitter, hue.2 + jitter];
    let period = 3 + rng.range_usize(0, 3);

    for y in 0..IMG {
        for x in 0..IMG {
            let dx = x as f32 - cx;
            let dy = y as f32 - cy;
            let on = match class {
                0 => {
                    let d = dx.abs().max(dy.abs());
                    d <= r && d >= r - 2.0
                }
                1 => dx.abs().max(dy.abs()) <= r,
                2 => (dx * dx + dy * dy).sqrt() <= r,
                3 => dx.abs() <= 1.5 || dy.abs() <= 1.5,
                4 => (y / period) % 2 == 0,
                5 => (x / period) % 2 == 0,
                6 => ((x + y) / period) % 2 == 0,
                _ => (x / period) % 2 == (y / period) % 2,
            };
            let base = if on { 1.0 } else { 0.0 };
            for c in 0..CH {
                let noise = 0.15 * (rng.next_f32() - 0.5);
                img[(y * IMG + x) * CH + c] = base * color[c] + noise;
            }
        }
    }
    img
}

/// Patchify HWC image into (N_PATCHES, PATCH_DIM), row-major patches.
pub fn patchify(img: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; N_PATCHES * PATCH_DIM];
    for py in 0..GRID {
        for px in 0..GRID {
            let p = py * GRID + px;
            let mut k = 0;
            for dy in 0..PATCH {
                for dx in 0..PATCH {
                    let (y, x) = (py * PATCH + dy, px * PATCH + dx);
                    for c in 0..CH {
                        out[p * PATCH_DIM + k] = img[(y * IMG + x) * CH + c];
                        k += 1;
                    }
                }
            }
        }
    }
    out
}

/// A batch of patchified images: x (B, N_PATCHES, PATCH_DIM), y (B,).
pub fn vision_batch(rng: &mut Rng, batch: usize) -> crate::data::Batch {
    let mut xs = Vec::with_capacity(batch * N_PATCHES * PATCH_DIM);
    let mut labels = Vec::with_capacity(batch);
    for _ in 0..batch {
        let class = rng.below(N_CLASSES as u64) as usize;
        let img = render(class, rng);
        xs.extend_from_slice(&patchify(&img));
        labels.push(class as i32);
    }
    crate::data::Batch {
        x: HostTensor::f32(vec![batch, N_PATCHES, PATCH_DIM], xs),
        y: HostTensor::i32(vec![batch], labels.clone()),
        labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let mut rng = Rng::new(0);
        let b = vision_batch(&mut rng, 4);
        assert_eq!(b.x.shape(), &[4, N_PATCHES, PATCH_DIM]);
        assert_eq!(b.y.shape(), &[4]);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean intra-class pixel distance < mean inter-class distance
        let mut rng = Rng::new(1);
        let imgs: Vec<(usize, Vec<f32>)> = (0..N_CLASSES)
            .flat_map(|c| (0..4).map(move |_| c))
            .map(|c| (c, render(c, &mut Rng::new(rng.next_u64()))))
            .collect();
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        let mut intra = (0.0, 0);
        let mut inter = (0.0, 0);
        for i in 0..imgs.len() {
            for j in i + 1..imgs.len() {
                let d = dist(&imgs[i].1, &imgs[j].1);
                if imgs[i].0 == imgs[j].0 {
                    intra = (intra.0 + d, intra.1 + 1);
                } else {
                    inter = (inter.0 + d, inter.1 + 1);
                }
            }
        }
        assert!(intra.0 / intra.1 as f32 <= inter.0 / inter.1 as f32);
    }

    #[test]
    fn patchify_preserves_energy() {
        let mut rng = Rng::new(2);
        let img = render(1, &mut rng);
        let patches = patchify(&img);
        let e1: f32 = img.iter().map(|x| x * x).sum();
        let e2: f32 = patches.iter().map(|x| x * x).sum();
        assert!((e1 - e2).abs() < 1e-3);
    }

    #[test]
    fn pixel_range_sane() {
        let mut rng = Rng::new(3);
        for c in 0..N_CLASSES {
            let img = render(c, &mut rng);
            assert!(img.iter().all(|&x| (-0.5..=1.5).contains(&x)));
        }
    }
}
