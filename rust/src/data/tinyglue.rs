//! tinyGLUE: eight synthetic sequence-classification tasks mirroring the
//! structure (single/pair-sentence, classification/ordinal) of the GLUE
//! tasks in the paper's Table 1.
//!
//! Design constraints:
//!  * every task is solvable by token-pair matching / counting — exactly
//!    the computations attention performs — so attention fidelity (what
//!    HAD distills) is the bottleneck, as in the paper;
//!  * RTE/MRPC analogs are intentionally harder (fewer distinguishing
//!    tokens, overlapping distributions) matching the paper's observation
//!    that "all methods significantly struggle with RTE and MRPC";
//!  * MNLI has matched/mismatched eval domains (token-range shift).
//!
//! Sequence layout (n_ctx = 128, vocab = 256, 4 label slots):
//!   [CLS] seg_a... [SEP] seg_b... [SEP] [PAD]...

use super::{TaskGen, CLS, PAD, SEP, TOK0};
use crate::util::rng::Rng;

/// Content token helper: tokens TOK0..vocab are content space.
const VOCAB: i32 = 256;
/// negation marker used by NLI-style tasks
const NEG: i32 = 3;
/// sentiment lexicons
const POS_LEX: std::ops::Range<i32> = 16..48;
const NEG_LEX: std::ops::Range<i32> = 48..80;

fn fill_random(rng: &mut Rng, out: &mut [i32], lo: i32, hi: i32) {
    for x in out.iter_mut() {
        *x = lo + rng.below((hi - lo) as u64) as i32;
    }
}

/// Write CLS seg_a SEP seg_b SEP, padding the rest.
fn compose(x: &mut [i32], seg_a: &[i32], seg_b: &[i32]) {
    x.fill(PAD);
    x[0] = CLS;
    let mut i = 1;
    for &t in seg_a {
        x[i] = t;
        i += 1;
    }
    x[i] = SEP;
    i += 1;
    for &t in seg_b {
        x[i] = t;
        i += 1;
    }
    x[i] = SEP;
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlueTask {
    Mnli,      // 3-way entailment, matched/mismatched domains
    Qqp,       // paraphrase detection
    Qnli,      // question/answer containment
    Sst2,      // sentiment by lexicon counting
    Cola,      // bigram-grammar acceptability
    Stsb,      // overlap similarity, 4 ordinal buckets
    Mrpc,      // hard paraphrase (same topic distractors)
    Rte,       // binary entailment, low-signal
}

impl GlueTask {
    pub const ALL: [GlueTask; 8] = [
        GlueTask::Mnli,
        GlueTask::Qqp,
        GlueTask::Qnli,
        GlueTask::Sst2,
        GlueTask::Cola,
        GlueTask::Stsb,
        GlueTask::Mrpc,
        GlueTask::Rte,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            GlueTask::Mnli => "MNLI",
            GlueTask::Qqp => "QQP",
            GlueTask::Qnli => "QNLI",
            GlueTask::Sst2 => "SST-2",
            GlueTask::Cola => "CoLA",
            GlueTask::Stsb => "STS-B",
            GlueTask::Mrpc => "MRPC",
            GlueTask::Rte => "RTE",
        }
    }

    /// Metric used in the Table-1 analog (matches the GLUE conventions).
    pub fn metric(&self) -> &'static str {
        match self {
            GlueTask::Cola => "matthews",
            GlueTask::Stsb => "pearson",
            _ => "accuracy",
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            GlueTask::Mnli => 3,
            GlueTask::Stsb => 4,
            _ => 2,
        }
    }
}

/// Generator for one task. `domain_shift` selects the MNLI "mismatched"
/// token domain for eval.
pub struct GlueGen {
    pub task: GlueTask,
    pub domain_shift: bool,
    seg_len: usize,
}

impl GlueGen {
    pub fn new(task: GlueTask) -> GlueGen {
        GlueGen { task, domain_shift: false, seg_len: 24 }
    }

    pub fn mismatched(task: GlueTask) -> GlueGen {
        GlueGen { task, domain_shift: true, seg_len: 24 }
    }

    /// Content token range for the current domain.
    fn domain(&self) -> (i32, i32) {
        if self.domain_shift {
            (128, VOCAB) // mismatched: disjoint upper half of the vocab
        } else {
            (TOK0 + 80, 128) // matched: mid-range, clear of the lexicons
        }
    }
}

impl TaskGen for GlueGen {
    fn n_classes(&self) -> usize {
        self.task.n_classes()
    }

    fn name(&self) -> &str {
        self.task.name()
    }

    fn sample(&self, rng: &mut Rng, x: &mut [i32]) -> i32 {
        let l = self.seg_len;
        let (lo, hi) = self.domain();
        let mut a = vec![0i32; l];
        let mut b = vec![0i32; l];
        match self.task {
            GlueTask::Mnli | GlueTask::Rte => {
                // premise: random content; hypothesis by label
                fill_random(rng, &mut a, lo, hi);
                let three_way = self.task == GlueTask::Mnli;
                let label = rng.below(if three_way { 3 } else { 2 }) as i32;
                match label {
                    0 => {
                        // entailment: hypothesis = subset of premise tokens
                        for i in 0..l {
                            b[i] = a[rng.range_usize(0, l)];
                        }
                    }
                    1 if three_way => {
                        // neutral: same domain, fresh tokens
                        fill_random(rng, &mut b, lo, hi);
                    }
                    _ => {
                        // contradiction / non-entailment: subset + negation
                        for i in 0..l {
                            b[i] = a[rng.range_usize(0, l)];
                        }
                        // RTE analog: weaker signal — only ONE negation
                        // marker hidden among content (low-signal task)
                        let n_neg = if three_way { 3 } else { 1 };
                        for _ in 0..n_neg {
                            b[rng.range_usize(0, l)] = NEG;
                        }
                    }
                }
                compose(x, &a, &b);
                label
            }
            GlueTask::Qqp | GlueTask::Mrpc => {
                fill_random(rng, &mut a, lo, hi);
                let label = rng.below(2) as i32;
                if label == 1 {
                    // paraphrase: shuffled copy with light noise
                    b.copy_from_slice(&a);
                    rng.shuffle(&mut b);
                    let noise = if self.task == GlueTask::Mrpc { 4 } else { 2 };
                    for _ in 0..noise {
                        b[rng.range_usize(0, l)] = lo + rng.below((hi - lo) as u64) as i32;
                    }
                } else if self.task == GlueTask::Mrpc {
                    // hard negative: share HALF the tokens (same topic)
                    for i in 0..l {
                        b[i] = if i % 2 == 0 {
                            a[rng.range_usize(0, l)]
                        } else {
                            lo + rng.below((hi - lo) as u64) as i32
                        };
                    }
                    rng.shuffle(&mut b);
                } else {
                    fill_random(rng, &mut b, lo, hi);
                }
                compose(x, &a, &b);
                label
            }
            GlueTask::Qnli => {
                // question: contains a probe token Q; sentence either
                // contains the "answer pair" (Q, Q+1 adjacent) or not
                fill_random(rng, &mut a, lo, hi);
                fill_random(rng, &mut b, lo, hi);
                let probe = lo + rng.below((hi - lo - 1) as u64) as i32;
                a[0] = probe;
                let label = rng.below(2) as i32;
                if label == 1 {
                    let pos = rng.range_usize(0, l - 1);
                    b[pos] = probe;
                    b[pos + 1] = probe + 1;
                }
                compose(x, &a, &b);
                label
            }
            GlueTask::Sst2 => {
                // sentiment: which lexicon dominates (counting task)
                let label = rng.below(2) as i32;
                let (major, minor) = if label == 1 {
                    (POS_LEX, NEG_LEX)
                } else {
                    (NEG_LEX, POS_LEX)
                };
                let n_major = l / 2 + 2 + rng.range_usize(0, 4);
                for (i, t) in a.iter_mut().enumerate() {
                    *t = if i < n_major {
                        major.start + rng.below((major.end - major.start) as u64) as i32
                    } else {
                        minor.start + rng.below((minor.end - minor.start) as u64) as i32
                    };
                }
                rng.shuffle(&mut a);
                fill_random(rng, &mut b, lo, hi); // filler segment
                compose(x, &a, &b);
                label
            }
            GlueTask::Cola => {
                // grammar: even positions hold tokens with even offset,
                // odd positions odd offset ("agreement rule"); corrupt k
                // positions for unacceptable sequences
                for (i, t) in a.iter_mut().enumerate() {
                    let off = rng.below(((hi - lo) / 2) as u64) as i32 * 2;
                    *t = lo + off + (i as i32 % 2);
                }
                let label = rng.below(2) as i32;
                if label == 0 {
                    for _ in 0..3 {
                        let i = rng.range_usize(0, l);
                        a[i] ^= 1; // flip parity: breaks the rule
                    }
                }
                fill_random(rng, &mut b, lo, hi);
                compose(x, &a, &b);
                label
            }
            GlueTask::Stsb => {
                // similarity: overlap fraction in {~0, ~1/3, ~2/3, ~1}
                fill_random(rng, &mut a, lo, hi);
                let label = rng.below(4) as i32;
                let n_shared = (l * label as usize) / 3;
                for i in 0..l {
                    b[i] = if i < n_shared {
                        a[i]
                    } else {
                        lo + rng.below((hi - lo) as u64) as i32
                    };
                }
                rng.shuffle(&mut b);
                compose(x, &a, &b);
                label
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::token_batch;

    #[test]
    fn all_tasks_generate_valid_batches() {
        let mut rng = Rng::new(1);
        for task in GlueTask::ALL {
            let gen = GlueGen::new(task);
            let b = token_batch(&gen, &mut rng, 8, 128);
            let xs = b.x.as_i32().unwrap();
            assert!(xs.iter().all(|&t| (0..VOCAB).contains(&t)), "{task:?}");
            for &y in &b.labels {
                assert!((y as usize) < task.n_classes(), "{task:?} label {y}");
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let mut rng = Rng::new(2);
        for task in GlueTask::ALL {
            let gen = GlueGen::new(task);
            let mut counts = vec![0usize; task.n_classes()];
            let mut x = vec![0i32; 128];
            for _ in 0..600 {
                counts[gen.sample(&mut rng, &mut x) as usize] += 1;
            }
            for (c, &n) in counts.iter().enumerate() {
                assert!(
                    n > 600 / task.n_classes() / 2,
                    "{task:?} class {c} undersampled: {counts:?}"
                );
            }
        }
    }

    #[test]
    fn mismatched_domain_disjoint() {
        let mut rng = Rng::new(3);
        let gen = GlueGen::mismatched(GlueTask::Mnli);
        let mut x = vec![0i32; 128];
        gen.sample(&mut rng, &mut x);
        // content tokens in x (beyond specials) must be >= 128
        assert!(x.iter().all(|&t| t < 8 || t >= 128));
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = GlueGen::new(GlueTask::Qqp);
        let mut a_rng = Rng::new(42);
        let mut b_rng = Rng::new(42);
        let mut xa = vec![0i32; 128];
        let mut xb = vec![0i32; 128];
        let la = gen.sample(&mut a_rng, &mut xa);
        let lb = gen.sample(&mut b_rng, &mut xb);
        assert_eq!(la, lb);
        assert_eq!(xa, xb);
    }
}
