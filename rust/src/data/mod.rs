//! Synthetic task suites standing in for the paper's datasets
//! (DESIGN.md §3 substitutions):
//!
//! * `tinyglue` — 8 sequence-classification tasks with GLUE-shaped
//!   structure (Table 1 analog).
//! * `vision`  — procedural shape images, patchified for the ViT analog
//!   (Table 2 / Figure 3).
//! * `longqa`  — needle-in-haystack multiple-choice QA over long synthetic
//!   documents (QuALITY / Figure 5 analog).
//!
//! All generators are deterministic in the seed, emit fixed-shape batches
//! matching the artifact signatures, and split train/eval by disjoint seed
//! streams.

pub mod longqa;
pub mod tinyglue;
pub mod vision;

use crate::runtime::HostTensor;

/// Reserved vocabulary for token-mode tasks (vocab = 256 in the configs).
pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
/// First free content token.
pub const TOK0: i32 = 8;

/// A fixed-size batch ready to feed an artifact.
#[derive(Clone, Debug)]
pub struct Batch {
    /// (B, n_ctx) i32 for token mode, (B, n_patches, input_dim) f32 dense.
    pub x: HostTensor,
    /// (B,) labels.
    pub y: HostTensor,
    pub labels: Vec<i32>,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.labels.len()
    }
}

/// A generator of (example, label) pairs at fixed shape.
pub trait TaskGen {
    /// Number of classes (labels are in 0..n_classes).
    fn n_classes(&self) -> usize;

    /// Sample one example into `x` (flattened) and return its label.
    fn sample(&self, rng: &mut crate::util::rng::Rng, x: &mut [i32]) -> i32;

    /// Human-readable task name (report rows).
    fn name(&self) -> &str;
}

/// Assemble a token-mode batch from any TaskGen.
pub fn token_batch(
    gen: &dyn TaskGen,
    rng: &mut crate::util::rng::Rng,
    batch: usize,
    n_ctx: usize,
) -> Batch {
    let mut xs = vec![PAD; batch * n_ctx];
    let mut labels = Vec::with_capacity(batch);
    for b in 0..batch {
        let label = gen.sample(rng, &mut xs[b * n_ctx..(b + 1) * n_ctx]);
        labels.push(label);
    }
    Batch {
        x: HostTensor::i32(vec![batch, n_ctx], xs),
        y: HostTensor::i32(vec![batch], labels.clone()),
        labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    struct Dummy;
    impl TaskGen for Dummy {
        fn n_classes(&self) -> usize {
            2
        }
        fn sample(&self, rng: &mut Rng, x: &mut [i32]) -> i32 {
            let label = (rng.next_u32() % 2) as i32;
            x[0] = CLS;
            x[1] = TOK0 + label;
            label
        }
        fn name(&self) -> &str {
            "dummy"
        }
    }

    #[test]
    fn token_batch_shapes() {
        let mut rng = Rng::new(0);
        let b = token_batch(&Dummy, &mut rng, 4, 16);
        assert_eq!(b.x.shape(), &[4, 16]);
        assert_eq!(b.y.shape(), &[4]);
        assert_eq!(b.batch_size(), 4);
        // CLS always at position 0
        let xs = b.x.as_i32().unwrap();
        for i in 0..4 {
            assert_eq!(xs[i * 16], CLS);
        }
    }
}
