//! Long-context needle QA: the QuALITY analog (paper §4.3, Figure 5).
//!
//! Each example is a virtual document of VIRTUAL_LEN tokens containing
//! planted facts "[KEY_s VAL]" for several slots, truncated to the model's
//! n_ctx exactly as the paper truncates QuALITY to each context limit. The
//! query asks for one slot; candidates list 4 values; the label is the
//! candidate matching the document's value for that slot.
//!
//! Accuracy therefore improves with context length for the same underlying
//! distribution — if truncation dropped the queried fact, only chance
//! accuracy is available — reproducing Figure 5's rising trend.

use super::{Batch, CLS, PAD, SEP};
use crate::runtime::HostTensor;
use crate::util::rng::Rng;

/// Virtual (pre-truncation) document length, matching the longest model.
pub const VIRTUAL_LEN: usize = 1024;
/// Number of fact slots planted per document.
pub const N_SLOTS: usize = 8;
pub const N_CANDIDATES: usize = 4;

/// token space layout
const KEY0: i32 = 8; // KEY_s = KEY0 + s (s < N_SLOTS)
const QUERY0: i32 = 24; // QUERY_s = QUERY0 + s
const VAL0: i32 = 64; // values: VAL0..VAL0+128
const N_VALS: u64 = 128;
const FILLER0: i32 = 224; // filler tokens: 224..256
const N_FILLER: u64 = 32;

/// Tokens reserved at the tail for the question/candidates section.
pub const QUESTION_LEN: usize = 2 + 1 + 1 + N_CANDIDATES; // SEP q SEP cands + margin

pub struct LongQaGen {
    pub n_ctx: usize,
}

impl LongQaGen {
    pub fn new(n_ctx: usize) -> LongQaGen {
        assert!(n_ctx >= 32, "context too small for the QA scaffold");
        LongQaGen { n_ctx }
    }

    /// Sample one example; returns the label in 0..4.
    pub fn sample(&self, rng: &mut Rng, x: &mut [i32]) -> i32 {
        assert_eq!(x.len(), self.n_ctx);
        // 1) virtual document: filler + planted facts at random positions
        let mut doc = vec![0i32; VIRTUAL_LEN];
        for t in doc.iter_mut() {
            *t = FILLER0 + rng.below(N_FILLER) as i32;
        }
        let mut slot_vals = [0i32; N_SLOTS];
        let mut positions = [0usize; N_SLOTS];
        for s in 0..N_SLOTS {
            slot_vals[s] = VAL0 + rng.below(N_VALS) as i32;
            // plant uniformly over the virtual doc (pairs never collide
            // thanks to slot-striped position ranges)
            let stripe = VIRTUAL_LEN / N_SLOTS;
            let pos = s * stripe + rng.range_usize(0, stripe - 2);
            doc[pos] = KEY0 + s as i32;
            doc[pos + 1] = slot_vals[s];
            positions[s] = pos;
        }

        // 2) truncate to the model's window, leaving room for the question
        let doc_budget = self.n_ctx - 1 - QUESTION_LEN;
        let visible = &doc[..doc_budget.min(VIRTUAL_LEN)];

        // 3) pick the queried slot and build candidates
        let q = rng.below(N_SLOTS as u64) as usize;
        let truth = slot_vals[q];
        let mut cands = [0i32; N_CANDIDATES];
        let correct = rng.below(N_CANDIDATES as u64) as usize;
        for (i, c) in cands.iter_mut().enumerate() {
            if i == correct {
                *c = truth;
            } else {
                // distractor: a different value
                loop {
                    let v = VAL0 + rng.below(N_VALS) as i32;
                    if v != truth {
                        *c = v;
                        break;
                    }
                }
            }
        }

        // 4) emit: CLS doc SEP QUERY_q SEP cands PAD*
        x.fill(PAD);
        x[0] = CLS;
        x[1..1 + visible.len()].copy_from_slice(visible);
        let mut i = 1 + visible.len();
        x[i] = SEP;
        x[i + 1] = QUERY0 + q as i32;
        x[i + 2] = SEP;
        i += 3;
        for c in cands {
            x[i] = c;
            i += 1;
        }
        correct as i32
    }

    /// Probability the queried fact survives truncation (analytic check
    /// for the Figure-5 trend).
    pub fn fact_visibility(&self) -> f64 {
        let doc_budget = (self.n_ctx - 1 - QUESTION_LEN).min(VIRTUAL_LEN) as f64;
        (doc_budget / VIRTUAL_LEN as f64).min(1.0)
    }
}

/// Batch helper (token mode).
pub fn longqa_batch(gen: &LongQaGen, rng: &mut Rng, batch: usize) -> Batch {
    let n = gen.n_ctx;
    let mut xs = vec![PAD; batch * n];
    let mut labels = Vec::with_capacity(batch);
    for b in 0..batch {
        labels.push(gen.sample(rng, &mut xs[b * n..(b + 1) * n]));
    }
    Batch {
        x: HostTensor::i32(vec![batch, n], xs),
        y: HostTensor::i32(vec![batch], labels.clone()),
        labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shapes_and_labels() {
        for n_ctx in [128, 256, 512, 1024] {
            let gen = LongQaGen::new(n_ctx);
            let mut rng = Rng::new(n_ctx as u64);
            let mut x = vec![0i32; n_ctx];
            for _ in 0..20 {
                let y = gen.sample(&mut rng, &mut x);
                assert!((0..N_CANDIDATES as i32).contains(&y));
                assert_eq!(x[0], CLS);
            }
        }
    }

    #[test]
    fn correct_candidate_matches_planted_value_when_visible() {
        // at n_ctx = 1024+ everything is visible: the correct candidate
        // must appear in the doc right after its KEY token
        let gen = LongQaGen::new(1024);
        let mut rng = Rng::new(5);
        let mut x = vec![0i32; 1024];
        for _ in 0..50 {
            let y = gen.sample(&mut rng, &mut x) as usize;
            // find question: SEP q SEP
            let sep_positions: Vec<usize> =
                (0..x.len()).filter(|&i| x[i] == SEP).collect();
            let q_pos = sep_positions[sep_positions.len() - 2] + 1;
            let slot = x[q_pos] - QUERY0;
            let cand0 = q_pos + 2;
            let answer = x[cand0 + y];
            // locate KEY_slot in the doc region (before the first SEP;
            // doc tokens never collide with SEP)
            let doc_end = sep_positions[0];
            let key = KEY0 + slot;
            // doc budget is n_ctx-1-QUESTION_LEN < VIRTUAL_LEN: the fact
            // may straddle the truncation boundary — skip those samples
            let Some(kpos) = (1..doc_end).find(|&i| x[i] == key) else {
                continue;
            };
            if kpos + 1 >= doc_end {
                continue;
            }
            assert_eq!(x[kpos + 1], answer, "candidate must equal planted value");
        }
    }

    #[test]
    fn visibility_increases_with_context() {
        let v: Vec<f64> = [128, 256, 512, 1024]
            .iter()
            .map(|&n| LongQaGen::new(n).fact_visibility())
            .collect();
        assert!(v.windows(2).all(|w| w[0] < w[1] || w[1] >= 0.95));
        assert!(v[0] < 0.2 && v[3] > 0.9);
    }

    #[test]
    fn batch_shape() {
        let gen = LongQaGen::new(256);
        let mut rng = Rng::new(1);
        let b = longqa_batch(&gen, &mut rng, 4);
        assert_eq!(b.x.shape(), &[4, 256]);
    }
}
