//! Table-3 report and hardware scaling sweeps.

use super::attention_unit::{breakdown, Breakdown, Design, Workload};
use super::tech::Tech;

/// Render the paper's Table 3 (component x {SA, HAD} x {area, power}).
pub fn table3_text(tech: &Tech) -> String {
    let sa = breakdown(Design::Standard, Workload::paper(), tech);
    let had = breakdown(Design::Had, Workload::paper(), tech);
    render_comparison(&sa, &had)
}

pub fn render_comparison(sa: &Breakdown, had: &Breakdown) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Attention head @ n_ctx={}, d_model={}, N={}\n",
        sa.workload.n_ctx, sa.workload.d_model, had.workload.n_top
    ));
    out.push_str(&format!(
        "{:<10} {:>12} {:>12} {:>12} {:>12}\n",
        "Component", "SA mm^2", "HAD mm^2", "SA W", "HAD W"
    ));
    for (cs, ch) in sa.components.iter().zip(&had.components) {
        debug_assert_eq!(cs.name, ch.name);
        out.push_str(&format!(
            "{:<10} {:>12.3} {:>12.3} {:>12.3} {:>12.3}\n",
            cs.name, cs.area_mm2, ch.area_mm2, cs.power_w, ch.power_w
        ));
    }
    out.push_str(&format!(
        "{:<10} {:>12.3} {:>12.3} {:>12.3} {:>12.3}\n",
        "Total",
        sa.total_area(),
        had.total_area(),
        sa.total_power(),
        had.total_power()
    ));
    out.push_str(&format!(
        "Reduction: area {:.1}%  power {:.1}%\n",
        100.0 * (1.0 - had.total_area() / sa.total_area()),
        100.0 * (1.0 - had.total_power() / sa.total_power()),
    ));
    out
}

/// Sweep context length, N scaled linearly (the paper's §4.3 rule),
/// returning (n_ctx, sa_energy_nj, had_energy_nj, area_ratio).
pub fn context_sweep(tech: &Tech, contexts: &[usize]) -> Vec<(usize, f64, f64, f64)> {
    contexts
        .iter()
        .map(|&n| {
            let w = Workload {
                n_ctx: n,
                d_model: super::tech::PAPER_D_MODEL,
                n_top: (super::tech::PAPER_N_TOP * n / super::tech::PAPER_N_CTX).max(1),
            };
            let sa = breakdown(Design::Standard, w, tech);
            let had = breakdown(Design::Had, w, tech);
            (
                n,
                sa.energy_per_query_nj(tech),
                had.energy_per_query_nj(tech),
                had.total_area() / sa.total_area(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_contains_paper_totals() {
        let text = table3_text(&Tech::default());
        assert!(text.contains("31.795"), "{text}");
        assert!(text.contains("6.724"), "{text}");
        assert!(text.contains("25.491"), "{text}");
        assert!(text.contains("3.301"), "{text}");
    }

    #[test]
    fn sweep_energy_gap_grows_with_context() {
        let sweep = context_sweep(&Tech::default(), &[128, 256, 512, 1024]);
        let gaps: Vec<f64> = sweep.iter().map(|(_, sa, had, _)| sa / had).collect();
        assert!(gaps.iter().all(|&g| g > 2.0));
    }
}
