//! Component-level designs of one attention head engine:
//! standard BF16 attention (SA) vs the paper's CAM-based HAD unit.

use super::tech::Tech;

/// Workload geometry for one attention evaluation (one query vector
/// against an n_ctx-deep K/V cache, d_model-wide — the paper's Table-3
//  setting).
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub n_ctx: usize,
    pub d_model: usize,
    pub n_top: usize,
}

impl Workload {
    pub fn paper() -> Workload {
        Workload {
            n_ctx: super::tech::PAPER_N_CTX,
            d_model: super::tech::PAPER_D_MODEL,
            n_top: super::tech::PAPER_N_TOP,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Design {
    /// dense BF16 digital attention
    Standard,
    /// CAM XNOR scores + top-N sorter + sparse AV
    Had,
}

impl Design {
    pub fn label(&self) -> &'static str {
        match self {
            Design::Standard => "SA",
            Design::Had => "HAD",
        }
    }
}

/// One row of the Table-3 breakdown.
#[derive(Clone, Debug)]
pub struct Component {
    pub name: &'static str,
    pub area_mm2: f64,
    pub power_w: f64,
    /// cycles to process one query (fully-pipelined array model)
    pub cycles: f64,
}

/// Full breakdown for one design at one workload.
#[derive(Clone, Debug)]
pub struct Breakdown {
    pub design: Design,
    pub workload: Workload,
    pub components: Vec<Component>,
}

impl Breakdown {
    pub fn total_area(&self) -> f64 {
        self.components.iter().map(|c| c.area_mm2).sum()
    }

    pub fn total_power(&self) -> f64 {
        self.components.iter().map(|c| c.power_w).sum()
    }

    pub fn total_cycles(&self) -> f64 {
        self.components.iter().map(|c| c.cycles).sum()
    }

    /// energy per query in nJ at the model clock
    pub fn energy_per_query_nj(&self, tech: &Tech) -> f64 {
        // each component is active for its own cycles: E = P * t
        self.components
            .iter()
            .map(|c| c.power_w * (c.cycles / (tech.clock_ghz * 1e9)) * 1e9)
            .sum()
    }
}

/// Build the component breakdown for a design at a workload.
pub fn breakdown(design: Design, w: Workload, t: &Tech) -> Breakdown {
    let n = w.n_ctx as f64;
    let d = w.d_model as f64;
    let ntop = w.n_top.min(w.n_ctx) as f64;
    let components = match design {
        Design::Standard => {
            // Fully-parallel d x n BF16 MAC array for QK^T; the same-size
            // array for AV; softmax over n. One query per pipeline beat;
            // cycles ~ pipeline depth ~ log2(d) for the reduction tree.
            let qk_units = d * n;
            vec![
                Component {
                    name: "Q K",
                    area_mm2: qk_units * t.mac_area_um2 / 1e6,
                    power_w: qk_units * t.mac_power_uw / 1e6,
                    cycles: d.log2().ceil(),
                },
                Component { name: "Top N", area_mm2: 0.0, power_w: 0.0, cycles: 0.0 },
                Component {
                    name: "SoftMax",
                    area_mm2: t.softmax_fixed_mm2 + n * t.softmax_per_el_mm2,
                    power_w: t.softmax_fixed_w + n * t.softmax_per_el_w,
                    cycles: 4.0, // exp LUT + normalize, pipelined
                },
                Component {
                    name: "A V",
                    area_mm2: qk_units * t.mac_area_um2 / 1e6,
                    power_w: qk_units * t.mac_power_uw / 1e6,
                    cycles: n.log2().ceil(),
                },
            ]
        }
        Design::Had => {
            // CAM XNOR array scores all n keys in one associative match;
            // top-N via a comparator network; sparse AV gathers N rows.
            let cam_cells = d * n;
            let comparators = n * n.log2().ceil();
            let av_macs = ntop * d;
            vec![
                Component {
                    name: "Q K",
                    area_mm2: cam_cells * t.xnor_area_um2 / 1e6,
                    power_w: cam_cells * t.xnor_power_uw / 1e6,
                    cycles: 1.0, // associative match
                },
                Component {
                    name: "Top N",
                    area_mm2: comparators * t.comparator_area_um2 / 1e6,
                    power_w: comparators * t.comparator_power_uw / 1e6,
                    cycles: n.log2().ceil(),
                },
                Component {
                    name: "SoftMax",
                    area_mm2: t.softmax_fixed_mm2 + ntop * t.softmax_per_el_mm2,
                    power_w: t.softmax_fixed_w + ntop * t.softmax_per_el_w,
                    cycles: 4.0,
                },
                Component {
                    name: "A V",
                    area_mm2: av_macs * t.mac_area_um2 * t.sparse_area_factor / 1e6,
                    power_w: av_macs * t.mac_power_uw * t.sparse_power_factor / 1e6,
                    cycles: ntop.log2().ceil(),
                },
            ]
        }
    };
    Breakdown { design, workload: w, components }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table3_totals() {
        let t = Tech::default();
        let sa = breakdown(Design::Standard, Workload::paper(), &t);
        let had = breakdown(Design::Had, Workload::paper(), &t);
        assert!((sa.total_area() - 31.795).abs() < 0.01, "{}", sa.total_area());
        assert!((sa.total_power() - 25.491).abs() < 0.01, "{}", sa.total_power());
        assert!((had.total_area() - 6.724).abs() < 0.01, "{}", had.total_area());
        assert!((had.total_power() - 3.301).abs() < 0.01, "{}", had.total_power());
    }

    #[test]
    fn reproduces_table3_components() {
        let t = Tech::default();
        let sa = breakdown(Design::Standard, Workload::paper(), &t);
        let had = breakdown(Design::Had, Workload::paper(), &t);
        let row = |b: &Breakdown, name: &str| -> (f64, f64) {
            let c = b.components.iter().find(|c| c.name == name).unwrap();
            (c.area_mm2, c.power_w)
        };
        assert!((row(&sa, "Q K").0 - 15.880).abs() < 1e-3);
        assert!((row(&had, "Q K").0 - 1.108).abs() < 1e-3);
        assert!((row(&had, "Top N").1 - 0.009).abs() < 1e-3);
        assert!((row(&sa, "SoftMax").0 - 0.035).abs() < 1e-3);
        assert!((row(&had, "A V").0 - 5.591).abs() < 1e-3);
    }

    #[test]
    fn paper_reduction_percentages() {
        let t = Tech::default();
        let sa = breakdown(Design::Standard, Workload::paper(), &t);
        let had = breakdown(Design::Had, Workload::paper(), &t);
        let area_red = 100.0 * (1.0 - had.total_area() / sa.total_area());
        let power_red = 100.0 * (1.0 - had.total_power() / sa.total_power());
        // paper: "79% area reduction and 87% power reduction"
        assert!((area_red - 79.0).abs() < 1.0, "area reduction {area_red}");
        assert!((power_red - 87.0).abs() < 1.0, "power reduction {power_red}");
    }

    #[test]
    fn scaling_monotone_in_context() {
        let t = Tech::default();
        let mut prev_area = 0.0;
        for n in [128usize, 256, 512, 1024] {
            let w = Workload { n_ctx: n, d_model: 1024, n_top: 30 * n / 256 };
            let b = breakdown(Design::Had, w, &t);
            assert!(b.total_area() > prev_area);
            prev_area = b.total_area();
        }
    }

    #[test]
    fn had_energy_below_sa_energy() {
        let t = Tech::default();
        let sa = breakdown(Design::Standard, Workload::paper(), &t);
        let had = breakdown(Design::Had, Workload::paper(), &t);
        assert!(had.energy_per_query_nj(&t) < sa.energy_per_query_nj(&t) / 3.0);
    }
}
