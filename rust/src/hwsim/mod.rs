//! Custom-hardware cost simulator (paper §4.4, Table 3): a CAM-based HAD
//! attention unit vs a conventional BF16 digital attention unit, with a
//! component-level area/power/energy model calibrated at the paper's
//! workload and extrapolated across (n_ctx, d_model, N).

pub mod attention_unit;
pub mod report;
pub mod tech;

pub use attention_unit::{breakdown, Breakdown, Component, Design, Workload};
pub use report::{context_sweep, render_comparison, table3_text};
pub use tech::Tech;
