//! Technology constants for the custom-hardware cost model.
//!
//! The paper (§4.4, Table 3) synthesized a smaller Verilog module with
//! Synopsys Design Compiler and scaled to the full design; absolute
//! constants are not published. We therefore derive per-unit costs by
//! calibrating the component model AT THE PAPER'S WORKLOAD — QK
//! (1x1024)x(1024x256), AV (1x256)x(256x1024), N=30 — so the Table-3
//! totals are reproduced exactly, then use the same constants to
//! extrapolate to other (n, d, N) points (energy curves, serving costs).
//! Every constant's derivation is recorded here:
//!
//!   bf16 MAC:    15.880 mm^2 / (1024*256 units) = 60.58 um^2;
//!                12.730 W    / (1024*256)       = 48.56 uW    (SA QK row)
//!   CAM XNOR:     1.108 mm^2 / (1024*256 cells) =  4.23 um^2;
//!                 0.127 W    / (1024*256)       =  0.48 uW    (HAD QK row)
//!   comparator:   0.008 mm^2 / (256*log2(256))  =  3.91 um^2;
//!                 0.009 W    / 2048             =  4.39 uW    (HAD TopN row)
//!   softmax:     fixed + per-element, solved from the SA (256 el) and
//!                HAD (30 el) rows simultaneously.
//!   sparse AV:   bf16 MACs on N rows plus a gather crossbar; the
//!                area/power factors are solved from the HAD AV row.

/// Per-unit technology constants (um^2 / uW at the synthesis corner).
#[derive(Clone, Copy, Debug)]
pub struct Tech {
    pub mac_area_um2: f64,
    pub mac_power_uw: f64,
    pub xnor_area_um2: f64,
    pub xnor_power_uw: f64,
    pub comparator_area_um2: f64,
    pub comparator_power_uw: f64,
    pub softmax_fixed_mm2: f64,
    pub softmax_per_el_mm2: f64,
    pub softmax_fixed_w: f64,
    pub softmax_per_el_w: f64,
    /// gather-crossbar overhead multipliers on the sparse AV array
    pub sparse_area_factor: f64,
    pub sparse_power_factor: f64,
    /// clock for the latency/energy model
    pub clock_ghz: f64,
}

/// The paper's calibration workload.
pub const PAPER_N_CTX: usize = 256;
pub const PAPER_D_MODEL: usize = 1024;
pub const PAPER_N_TOP: usize = 30;

impl Default for Tech {
    fn default() -> Self {
        let units = (PAPER_D_MODEL * PAPER_N_CTX) as f64; // 262144
        let comparators = (PAPER_N_CTX as f64) * (PAPER_N_CTX as f64).log2(); // 2048
        // softmax: solve the 2x2 system from the SA(256el)/HAD(30el) rows
        let sm_per_a = (0.035 - 0.017) / (PAPER_N_CTX - PAPER_N_TOP) as f64;
        let sm_fix_a = 0.035 - PAPER_N_CTX as f64 * sm_per_a;
        let sm_per_p = (0.031 - 0.024) / (PAPER_N_CTX - PAPER_N_TOP) as f64;
        let sm_fix_p = 0.031 - PAPER_N_CTX as f64 * sm_per_p;
        // sparse AV factors from the HAD AV row
        let av_macs = (PAPER_N_TOP * PAPER_D_MODEL) as f64; // 30720
        let mac_area = 15.880 / units * 1e6; // um^2
        let mac_power = 12.730 / units * 1e6; // uW
        Tech {
            mac_area_um2: mac_area,
            mac_power_uw: mac_power,
            xnor_area_um2: 1.108 / units * 1e6,
            xnor_power_uw: 0.127 / units * 1e6,
            comparator_area_um2: 0.008 / comparators * 1e6,
            comparator_power_uw: 0.009 / comparators * 1e6,
            softmax_fixed_mm2: sm_fix_a,
            softmax_per_el_mm2: sm_per_a,
            softmax_fixed_w: sm_fix_p,
            softmax_per_el_w: sm_per_p,
            sparse_area_factor: 5.591 / (av_macs * mac_area / 1e6),
            sparse_power_factor: 3.141 / (av_macs * mac_power / 1e6),
            clock_ghz: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_in_plausible_ranges() {
        let t = Tech::default();
        // bf16 MAC tens of um^2; CAM cell an order of magnitude smaller
        assert!(t.mac_area_um2 > 30.0 && t.mac_area_um2 < 120.0);
        assert!(t.xnor_area_um2 < t.mac_area_um2 / 5.0);
        assert!(t.xnor_power_uw < t.mac_power_uw / 20.0);
        assert!(t.sparse_area_factor > 1.0 && t.sparse_area_factor < 5.0);
        assert!(t.softmax_fixed_mm2 > 0.0 && t.softmax_per_el_mm2 > 0.0);
    }
}
