//! Observability substrate: structured tracing + typed metrics.
//!
//! Three pieces (DESIGN.md §Substrates — replaces tracing/metrics crates,
//! which the offline registry cannot provide):
//!
//! * [`span`] — a thread-local ring-buffer **span recorder**. Each span
//!   carries an id, parent id, static stage label, start/duration in
//!   microseconds since the process trace epoch, and one `u64` payload
//!   (n_keys, page count, token count — stage-dependent). Recording costs
//!   one relaxed atomic load when tracing is disabled; when enabled via
//!   `HAD_TRACE=dir[,sample=N]` requests are sampled at the admission
//!   boundary (1 in N) and every stage under a sampled request records.
//!   Parent links are explicit (`SpanId` values travel with the request),
//!   so they survive the scoped-thread sharding in
//!   `util::threadpool::parallel_map_n` / `parallel_for_mut`, which spawn
//!   fresh threads per call and inherit no thread-local state.
//!
//! * [`registry`] — typed counters, gauges, and **log-bucketed bounded
//!   histograms**. Histograms are exact for values `<= 1024` (one bucket
//!   per microsecond) and log₂-bucketed with 16 sub-buckets per octave
//!   above, so percentile estimates carry at most one bucket (≈6.25%)
//!   relative error while memory stays O(1) in the number of samples.
//!   `coordinator::Metrics` is built on these instead of unbounded
//!   `Vec<u128>` sample buffers.
//!
//! * [`export`] — writes Chrome-trace-event JSON (loadable in Perfetto or
//!   `chrome://tracing`) from the span rings, plus append-only JSONL
//!   metric snapshots, both under the `HAD_TRACE` directory.

pub mod export;
pub mod registry;
pub mod span;

pub use export::{flush_trace, write_metrics_snapshot};
pub use registry::{Counter, Gauge, Histogram, Registry};
pub use span::{
    current, enter, record, record_as, root_span, sample_request, span, span_under, trace_dir,
    tracing, EnterGuard, Span, SpanId, SpanTimer,
};
