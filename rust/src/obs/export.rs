//! Trace + metrics export.
//!
//! * [`flush_trace`] — serializes every recorded span as a Chrome
//!   trace-event "complete" (`ph:"X"`) event into
//!   `$HAD_TRACE_DIR/trace.json`, loadable in Perfetto
//!   (<https://ui.perfetto.dev>) or `chrome://tracing`. Span ids, parent
//!   ids, and payloads travel in `args` so scripts (and humans) can
//!   rebuild the request tree; timestamps/durations are microseconds, the
//!   trace-event native unit.
//! * [`write_metrics_snapshot`] — appends one JSONL line per call to
//!   `$HAD_TRACE_DIR/metrics.jsonl` from a [`Registry`] snapshot; the
//!   scheduler calls it periodically while tracing so long runs leave a
//!   metrics timeline next to the spans.
//!
//! Both are no-ops (returning `None`) when `HAD_TRACE` is unset.

use std::io::{BufWriter, Write};
use std::path::PathBuf;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::obs::registry::Registry;
use crate::obs::span::{self, Span};
use crate::util::json::Json;

/// Write the full span buffer as Chrome-trace-event JSON under the
/// `HAD_TRACE` directory. Idempotent: each call rewrites the file with
/// everything recorded so far. Returns the path written, `None` when
/// tracing is disabled.
pub fn flush_trace() -> Option<PathBuf> {
    let dir = span::trace_dir()?;
    let path = PathBuf::from(&dir).join("trace.json");
    let (spans, dropped) = span::collect();
    match write_chrome_trace(&path, &spans, dropped) {
        Ok(()) => {
            crate::log_info!(
                "trace: wrote {} spans ({} dropped to ring wrap) to {}",
                spans.len(),
                dropped,
                path.display()
            );
            Some(path)
        }
        Err(e) => {
            crate::log_warn!("trace: failed to write {}: {e}", path.display());
            None
        }
    }
}

fn write_chrome_trace(path: &std::path::Path, spans: &[Span], dropped: u64) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    write!(w, "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[")?;
    // Metadata: process name + kernel backend, so a bare trace is
    // self-describing in the Perfetto UI.
    write!(
        w,
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{{\"name\":\"had ({})\"}}}}",
        crate::binary::KernelBackend::active().name()
    )?;
    write!(
        w,
        ",{{\"name\":\"trace_meta\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{{\"dropped_spans\":{dropped}}}}}"
    )?;
    for s in spans {
        // Stage names are static identifiers (no escaping needed).
        write!(
            w,
            ",{{\"name\":\"{}\",\"cat\":\"had\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
             \"ts\":{},\"dur\":{},\"args\":{{\"id\":{},\"parent\":{},\"payload\":{}}}}}",
            s.name, s.tid, s.start_us, s.dur_us, s.id, s.parent, s.payload
        )?;
    }
    write!(w, "]}}")?;
    w.flush()
}

/// Append one metrics-snapshot JSONL line (wall-clock stamped) to
/// `$HAD_TRACE_DIR/metrics.jsonl`. Returns the path, `None` when tracing
/// is disabled or the write fails.
pub fn write_metrics_snapshot(registry: &Registry) -> Option<PathBuf> {
    let dir = span::trace_dir()?;
    let path = PathBuf::from(&dir).join("metrics.jsonl");
    let ts_ms = SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis()).unwrap_or(0);
    let mut line = match registry.snapshot_json() {
        Json::Obj(m) => m,
        other => {
            let mut m = std::collections::BTreeMap::new();
            m.insert("snapshot".to_string(), other);
            m
        }
    };
    line.insert("ts_ms".to_string(), Json::num(ts_ms as f64));
    match crate::util::bench::write_jsonl(path.to_str()?, &[Json::Obj(line)]) {
        Ok(()) => Some(path),
        Err(e) => {
            crate::log_warn!("trace: failed to append {}: {e}", path.display());
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn chrome_trace_file_parses_and_contains_spans() {
        let dir = std::env::temp_dir().join(format!("had_obs_export_{}", std::process::id()));
        let path = dir.join("trace.json");
        let spans = vec![
            Span {
                id: 1,
                parent: 0,
                name: "request",
                start_us: 10,
                dur_us: 500,
                payload: 3,
                tid: 1,
            },
            Span {
                id: 2,
                parent: 1,
                name: "attention",
                start_us: 20,
                dur_us: 80,
                payload: 4096,
                tid: 2,
            },
        ];
        write_chrome_trace(&path, &spans, 7).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).expect("trace JSON parses");
        let events = j.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        // 2 metadata + 2 span events
        assert_eq!(events.len(), 4);
        let attn = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("attention"))
            .expect("attention event present");
        assert_eq!(attn.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert_eq!(attn.get("ts").and_then(|t| t.as_f64()), Some(20.0));
        assert_eq!(attn.get("dur").and_then(|t| t.as_f64()), Some(80.0));
        assert_eq!(attn.at(&["args", "parent"]).and_then(|p| p.as_f64()), Some(1.0));
        assert_eq!(attn.at(&["args", "payload"]).and_then(|p| p.as_f64()), Some(4096.0));
        let meta = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("trace_meta"))
            .expect("meta event present");
        assert_eq!(meta.at(&["args", "dropped_spans"]).and_then(|d| d.as_f64()), Some(7.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disabled_tracing_exports_nothing() {
        let _g = crate::obs::span::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::obs::span::set_enabled_for_tests(false, 1);
        assert!(flush_trace().is_none());
        assert!(write_metrics_snapshot(&Registry::new()).is_none());
    }

    #[test]
    fn metrics_snapshot_line_appends_and_parses() {
        let _g = crate::obs::span::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join(format!("had_obs_snap_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        // Point tracing at a temp dir via the test hook, then overwrite
        // the parsed config's dir by setting the env-independent path:
        // set_enabled_for_tests uses an empty dir, so exercise the write
        // through write_jsonl directly against the same line shape.
        let reg = Registry::new();
        reg.counter("ticks").add(3);
        reg.histogram("tick_us").record(120);
        let line = match reg.snapshot_json() {
            Json::Obj(mut m) => {
                m.insert("ts_ms".to_string(), Json::num(1.0));
                Json::Obj(m)
            }
            _ => unreachable!(),
        };
        let path = dir.join("metrics.jsonl");
        crate::util::bench::write_jsonl(path.to_str().unwrap(), &[line]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(text.lines().next().unwrap()).expect("snapshot line parses");
        assert_eq!(parsed.at(&["counters", "ticks"]).and_then(|v| v.as_f64()), Some(3.0));
        assert!(parsed.at(&["histograms", "tick_us", "p50"]).is_some());
        crate::obs::span::set_enabled_for_tests(false, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn end_to_end_flush_via_test_dir() {
        let _g = crate::obs::span::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join(format!("had_obs_flush_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        crate::obs::span::set_enabled_for_tests_with_dir(dir.to_str().unwrap(), 1);
        let root = crate::obs::span::sample_request();
        crate::obs::span::record_as(
            root,
            crate::obs::SpanId::NONE,
            "obs_test_flush_root",
            Instant::now(),
            42,
            0,
        );
        let path = flush_trace().expect("tracing enabled → path");
        let reg = Registry::new();
        reg.gauge("depth").set(2);
        let snap = write_metrics_snapshot(&reg).expect("snapshot written");
        crate::obs::span::set_enabled_for_tests(false, 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).expect("flushed trace parses");
        let events = j.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert!(
            events
                .iter()
                .any(|e| e.get("name").and_then(|n| n.as_str()) == Some("obs_test_flush_root")),
            "flushed trace contains the recorded span"
        );
        let snap_text = std::fs::read_to_string(&snap).unwrap();
        assert!(Json::parse(snap_text.lines().next().unwrap()).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
