//! Thread-local ring-buffer span recorder.
//!
//! Activation: `HAD_TRACE=dir[,sample=N]`. `dir` is where the exporter
//! writes `trace.json` / `metrics.jsonl`; `sample=N` records one request
//! in N (default 1 = every request). When the variable is unset every
//! entry point reduces to a single relaxed atomic load and no
//! thread-local storage is ever touched.
//!
//! Each recording thread owns a fixed-capacity ring (oldest spans are
//! overwritten once full, `dropped` counts the overflow), registered in a
//! global list so the exporter can collect across threads. The serving
//! stack shards work over *scoped* threads that live for one call
//! (`parallel_map_n`), so on thread exit the local ring drains into a
//! bounded global "retired" ring instead of leaking one Arc per short-
//! lived worker. Parent links never rely on thread identity: a `SpanId`
//! is plain data that travels with the request across shard boundaries.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Spans a single thread buffers before wrapping (64 B each).
const RING_CAP: usize = 16 * 1024;
/// Bound on spans preserved from already-exited threads.
const RETIRED_CAP: usize = 128 * 1024;

/// One recorded span. `start_us` is relative to the process trace epoch
/// (first tracing-related call), `payload` is stage-dependent (n_keys for
/// kernel spans, token counts for decode segments, stream counts for
/// scheduler ticks, ...).
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub id: u64,
    pub parent: u64,
    pub name: &'static str,
    pub start_us: u64,
    pub dur_us: u64,
    pub payload: u64,
    pub tid: u64,
}

/// Identifier linking child spans to their parent. `SpanId::NONE` (0)
/// means "not traced" — children of an untraced parent are no-ops, which
/// is how request-level sampling propagates through the stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanId(pub u64);

impl SpanId {
    pub const NONE: SpanId = SpanId(0);

    pub fn is_none(self) -> bool {
        self.0 == 0
    }
}

struct TraceConfig {
    dir: String,
    sample: u64,
}

// 0 = uninitialized, 1 = disabled, 2 = enabled.
static STATE: AtomicU8 = AtomicU8::new(0);
static CONFIG: Mutex<Option<TraceConfig>> = Mutex::new(None);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static SAMPLE_CTR: AtomicU64 = AtomicU64::new(0);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn since_epoch_us(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_micros() as u64
}

fn init() -> bool {
    // Racy double-init is harmless: both racers parse the same env var.
    let _ = epoch();
    let parsed = std::env::var("HAD_TRACE").ok().and_then(|v| parse_spec(&v));
    let on = parsed.is_some();
    *CONFIG.lock().unwrap() = parsed;
    STATE.store(if on { 2 } else { 1 }, Ordering::Release);
    on
}

fn parse_spec(spec: &str) -> Option<TraceConfig> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "0" {
        return None;
    }
    let mut parts = spec.split(',');
    let dir = parts.next().unwrap_or("").trim().to_string();
    if dir.is_empty() {
        return None;
    }
    let mut sample = 1u64;
    for p in parts {
        let p = p.trim();
        if let Some(n) = p.strip_prefix("sample=") {
            sample = n.trim().parse::<u64>().unwrap_or(1).max(1);
        } else if !p.is_empty() {
            crate::log_warn!("HAD_TRACE: ignoring unrecognized option '{p}'");
        }
    }
    Some(TraceConfig { dir, sample })
}

/// Is span recording active? One relaxed atomic load on the hot path.
#[inline]
pub fn tracing() -> bool {
    match STATE.load(Ordering::Relaxed) {
        0 => init(),
        1 => false,
        _ => true,
    }
}

/// Output directory from `HAD_TRACE`, when tracing is enabled. `None`
/// also when the configured dir is empty (the in-process test hook), so
/// recording can be exercised without the exporter touching the cwd.
pub fn trace_dir() -> Option<String> {
    if !tracing() {
        return None;
    }
    CONFIG.lock().unwrap().as_ref().map(|c| c.dir.clone()).filter(|d| !d.is_empty())
}

fn sample_n() -> u64 {
    CONFIG.lock().unwrap().as_ref().map_or(1, |c| c.sample)
}

/// Test hook: force tracing on/off in-process (bypasses `HAD_TRACE`).
/// Tests that flip this must serialize on their own lock and filter
/// collected spans by their own names/ids.
#[doc(hidden)]
pub fn set_enabled_for_tests(on: bool, sample: u64) {
    let _ = epoch();
    *CONFIG.lock().unwrap() = if on {
        Some(TraceConfig { dir: String::new(), sample: sample.max(1) })
    } else {
        None
    };
    STATE.store(if on { 2 } else { 1 }, Ordering::Release);
}

/// Test hook: enable tracing with an export directory (exercises the
/// exporter end to end without the env var).
#[doc(hidden)]
pub fn set_enabled_for_tests_with_dir(dir: &str, sample: u64) {
    let _ = epoch();
    *CONFIG.lock().unwrap() =
        Some(TraceConfig { dir: dir.to_string(), sample: sample.max(1) });
    STATE.store(2, Ordering::Release);
}

fn alloc_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Admission-boundary sampling decision: allocates a trace id for 1 in N
/// requests (N from `HAD_TRACE=dir,sample=N`), `SpanId::NONE` otherwise.
/// The id is the parent for every stage span of that request; record the
/// request's own umbrella span at completion with [`record_as`].
pub fn sample_request() -> SpanId {
    if !tracing() {
        return SpanId::NONE;
    }
    let n = sample_n();
    let tick = SAMPLE_CTR.fetch_add(1, Ordering::Relaxed);
    if tick % n != 0 {
        return SpanId::NONE;
    }
    SpanId(alloc_id())
}

// ---------------------------------------------------------------------------
// Ring storage
// ---------------------------------------------------------------------------

struct Ring {
    buf: Vec<Span>,
    head: usize,
    dropped: u64,
    tid: u64,
}

impl Ring {
    fn new(cap: usize, tid: u64) -> Ring {
        Ring { buf: Vec::with_capacity(cap), head: 0, dropped: 0, tid }
    }

    fn push(&mut self, mut s: Span) {
        s.tid = self.tid;
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(s);
        } else {
            self.buf[self.head] = s;
            self.head = (self.head + 1) % self.buf.len();
            self.dropped += 1;
        }
    }
}

static RINGS: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());
static RETIRED: Mutex<Option<Ring>> = Mutex::new(None);

/// Drains a thread's ring into the bounded retired ring when the thread
/// exits, so short-lived scoped workers don't leak one ring each.
struct LocalRing(Arc<Mutex<Ring>>);

impl Drop for LocalRing {
    fn drop(&mut self) {
        let mut rings = match RINGS.lock() {
            Ok(g) => g,
            Err(_) => return,
        };
        rings.retain(|r| !Arc::ptr_eq(r, &self.0));
        drop(rings);
        let mine = match self.0.lock() {
            Ok(g) => std::mem::replace(&mut *g, Ring::new(0, 0)),
            Err(_) => return,
        };
        if let Ok(mut retired) = RETIRED.lock() {
            let dst = retired.get_or_insert_with(|| Ring::new(RETIRED_CAP, 0));
            for s in mine.buf {
                dst.push(s);
            }
            dst.dropped += mine.dropped;
        }
    }
}

thread_local! {
    static LOCAL: std::cell::OnceCell<LocalRing> = const { std::cell::OnceCell::new() };
    static CURRENT: Cell<u64> = const { Cell::new(0) };
}

fn push_span(s: Span) {
    LOCAL.with(|cell| {
        let local = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let arc = Arc::new(Mutex::new(Ring::new(RING_CAP, tid)));
            RINGS.lock().unwrap().push(Arc::clone(&arc));
            LocalRing(arc)
        });
        local.0.lock().unwrap().push(s);
    });
}

/// Snapshot of all recorded spans (live rings + retired) and the total
/// number dropped to ring wraparound. Does not clear the rings.
pub fn collect() -> (Vec<Span>, u64) {
    let mut out = Vec::new();
    let mut dropped = 0u64;
    if let Ok(retired) = RETIRED.lock() {
        if let Some(r) = retired.as_ref() {
            out.extend_from_slice(&r.buf);
            dropped += r.dropped;
        }
    }
    let rings: Vec<Arc<Mutex<Ring>>> = RINGS.lock().unwrap().clone();
    for ring in rings {
        let g = ring.lock().unwrap();
        out.extend_from_slice(&g.buf);
        dropped += g.dropped;
    }
    (out, dropped)
}

// ---------------------------------------------------------------------------
// Recording API
// ---------------------------------------------------------------------------

/// Record a completed span with explicit timing (for retrospective spans
/// like queue wait, where start/duration are known from request
/// timestamps). Returns the new span's id, or `NONE` when not recorded.
/// A `NONE` parent means the owning request was not sampled, so the
/// child is dropped too — use [`root_span`] for genuinely parentless
/// activity.
pub fn record(
    parent: SpanId,
    name: &'static str,
    start: Instant,
    dur_us: u64,
    payload: u64,
) -> SpanId {
    if parent.is_none() || !tracing() {
        return SpanId::NONE;
    }
    let id = SpanId(alloc_id());
    record_as(id, parent, name, start, dur_us, payload);
    id
}

/// Record a completed span under a pre-allocated id (e.g. the request
/// umbrella span whose id was handed out by [`sample_request`] at
/// admission and recorded at reply time). No-op when `id` is `NONE`.
pub fn record_as(
    id: SpanId,
    parent: SpanId,
    name: &'static str,
    start: Instant,
    dur_us: u64,
    payload: u64,
) {
    if id.is_none() || !tracing() {
        return;
    }
    push_span(Span {
        id: id.0,
        parent: parent.0,
        name,
        start_us: since_epoch_us(start),
        dur_us,
        payload,
        tid: 0,
    });
}

/// The current thread's ambient parent span (set by [`enter`] or an
/// active [`SpanTimer`]). `NONE` outside any traced scope.
pub fn current() -> SpanId {
    SpanId(CURRENT.with(|c| c.get()))
}

/// Makes `parent` the ambient span for this thread until the guard drops.
/// This is how a request's trace id crosses `parallel_map_n` shard
/// boundaries: the worker closure calls `enter(req.trace)` and every
/// child span inside attaches correctly even though the worker thread was
/// just spawned.
pub fn enter(parent: SpanId) -> EnterGuard {
    let prev = CURRENT.with(|c| c.replace(parent.0));
    EnterGuard { prev }
}

pub struct EnterGuard {
    prev: u64,
}

impl Drop for EnterGuard {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.prev));
    }
}

/// RAII timed span: starts at construction, records at drop. While alive
/// it is the thread's ambient parent, so nested `span()` calls chain.
pub struct SpanTimer {
    id: u64,
    parent: u64,
    name: &'static str,
    start: Option<Instant>,
    payload: u64,
    prev: u64,
}

impl SpanTimer {
    fn new(active: bool, parent: u64, name: &'static str) -> SpanTimer {
        if !active {
            return SpanTimer { id: 0, parent: 0, name, start: None, payload: 0, prev: 0 };
        }
        let id = alloc_id();
        let prev = CURRENT.with(|c| c.replace(id));
        SpanTimer { id, parent, name, start: Some(Instant::now()), payload: 0, prev }
    }

    pub fn id(&self) -> SpanId {
        SpanId(self.id)
    }

    pub fn is_active(&self) -> bool {
        self.start.is_some()
    }

    /// Attach the stage payload (n_keys, page count, token count, ...).
    pub fn set_payload(&mut self, payload: u64) {
        self.payload = payload;
    }
}

impl Drop for SpanTimer {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        CURRENT.with(|c| c.set(self.prev));
        let dur_us = start.elapsed().as_micros() as u64;
        push_span(Span {
            id: self.id,
            parent: self.parent,
            name: self.name,
            start_us: since_epoch_us(start),
            dur_us,
            payload: self.payload,
            tid: 0,
        });
    }
}

/// Timed child span of the ambient parent. Inert (zero further cost)
/// when tracing is disabled or the thread is outside any traced scope —
/// the latter is what makes unsampled requests free.
pub fn span(name: &'static str) -> SpanTimer {
    let parent = CURRENT.with(|c| c.get());
    SpanTimer::new(parent != 0 && tracing(), parent, name)
}

/// Timed child span of an explicit parent (cross-thread handoff).
pub fn span_under(parent: SpanId, name: &'static str) -> SpanTimer {
    SpanTimer::new(!parent.is_none() && tracing(), parent.0, name)
}

/// Timed root span (no parent) — scheduler ticks and other per-process
/// activity that is not attributable to one request.
pub fn root_span(name: &'static str) -> SpanTimer {
    SpanTimer::new(tracing(), 0, name)
}

#[cfg(test)]
pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::parallel_map_n;

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn named(name: &str) -> Vec<Span> {
        collect().0.into_iter().filter(|s| s.name == name).collect()
    }

    #[test]
    fn disabled_path_records_nothing() {
        let _g = lock();
        set_enabled_for_tests(false, 1);
        assert!(!tracing());
        assert!(sample_request().is_none());
        assert!(record(SpanId(7), "obs_test_disabled", Instant::now(), 5, 0).is_none());
        {
            let mut t = span_under(SpanId(7), "obs_test_disabled");
            t.set_payload(9);
            assert!(!t.is_active());
        }
        {
            let t = root_span("obs_test_disabled");
            assert!(!t.is_active());
        }
        assert!(named("obs_test_disabled").is_empty(), "disabled recorder must be a no-op");
    }

    #[test]
    fn child_of_untraced_parent_is_noop() {
        let _g = lock();
        set_enabled_for_tests(true, 1);
        {
            let t = span_under(SpanId::NONE, "obs_test_unsampled");
            assert!(!t.is_active(), "NONE parent = unsampled request = free");
        }
        assert!(current().is_none());
        {
            let t = span("obs_test_unsampled");
            assert!(!t.is_active(), "no ambient scope, no span");
        }
        assert!(
            record(SpanId::NONE, "obs_test_unsampled", Instant::now(), 3, 0).is_none(),
            "retrospective child of an unsampled request is dropped"
        );
        set_enabled_for_tests(false, 1);
        assert!(named("obs_test_unsampled").is_empty());
    }

    #[test]
    fn sampling_keeps_one_in_n() {
        let _g = lock();
        set_enabled_for_tests(true, 4);
        // The tick counter is process-global, so concurrently running
        // server tests may interleave their own admissions while tracing
        // is force-enabled here; assert the 1-in-4 density with slack
        // rather than an exact phase-dependent count.
        let hits = (0..64).filter(|_| !sample_request().is_none()).count();
        set_enabled_for_tests(false, 1);
        assert!((8..=32).contains(&hits), "sample=4 keeps ~1 in 4, got {hits}/64");
    }

    #[test]
    fn timer_nesting_links_parent() {
        let _g = lock();
        set_enabled_for_tests(true, 1);
        let root_id;
        let child_id;
        {
            let root = root_span("obs_test_nest_root");
            root_id = root.id();
            assert_eq!(current(), root_id);
            let mut child = span("obs_test_nest_child");
            child.set_payload(42);
            child_id = child.id();
        }
        set_enabled_for_tests(false, 1);
        let roots = named("obs_test_nest_root");
        let children = named("obs_test_nest_child");
        let r = roots.iter().find(|s| s.id == root_id.0).expect("root recorded");
        let c = children.iter().find(|s| s.id == child_id.0).expect("child recorded");
        assert_eq!(r.parent, 0);
        assert_eq!(c.parent, r.id, "nested timer links to enclosing span");
        assert_eq!(c.payload, 42);
        assert!(c.start_us >= r.start_us);
    }

    #[test]
    fn parent_links_survive_parallel_map_sharding() {
        let _g = lock();
        set_enabled_for_tests(true, 1);
        let root = sample_request();
        assert!(!root.is_none());
        let items: Vec<u64> = (0..24).collect();
        // Fresh scoped threads per call: no thread-local inheritance. The
        // explicit SpanId is the only thing carrying the link.
        let ids = parallel_map_n(4, &items, |_, &x| {
            let _scope = enter(root);
            let mut t = span("obs_test_shard_child");
            t.set_payload(x);
            t.id().0
        });
        record_as(root, SpanId::NONE, "obs_test_shard_root", Instant::now(), 1, 0);
        set_enabled_for_tests(false, 1);
        let children = named("obs_test_shard_child");
        for (i, id) in ids.iter().enumerate() {
            let c = children
                .iter()
                .find(|s| s.id == *id)
                .unwrap_or_else(|| panic!("child {i} recorded (retired-ring drain)"));
            assert_eq!(c.parent, root.0, "shard child {i} keeps the request parent");
        }
        let payloads: std::collections::BTreeSet<u64> =
            children.iter().filter(|s| s.parent == root.0).map(|s| s.payload).collect();
        assert!(payloads.is_superset(&items.iter().copied().collect()), "all shards recorded");
        assert!(
            named("obs_test_shard_root").iter().any(|s| s.id == root.0),
            "umbrella span recorded under the pre-allocated id"
        );
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut r = Ring::new(4, 9);
        for i in 0..10u64 {
            r.push(Span {
                id: i,
                parent: 0,
                name: "w",
                start_us: i,
                dur_us: 0,
                payload: 0,
                tid: 0,
            });
        }
        assert_eq!(r.buf.len(), 4, "bounded");
        assert_eq!(r.dropped, 6);
        assert!(r.buf.iter().all(|s| s.tid == 9));
        let ids: Vec<u64> = r.buf.iter().map(|s| s.id).collect();
        assert!(ids.contains(&9), "newest survives wraparound");
        assert!(!ids.contains(&0), "oldest overwritten");
    }

    #[test]
    fn parse_spec_variants() {
        let _g = lock();
        let c = parse_spec("results/trace").unwrap();
        assert_eq!(c.dir, "results/trace");
        assert_eq!(c.sample, 1);
        let c = parse_spec("out, sample=8 ").unwrap();
        assert_eq!(c.dir, "out");
        assert_eq!(c.sample, 8);
        let c = parse_spec("out,sample=0").unwrap();
        assert_eq!(c.sample, 1, "sample clamped to >= 1");
        assert!(parse_spec("").is_none());
        assert!(parse_spec("0").is_none());
        assert!(parse_spec(" ,sample=2").is_none(), "empty dir disables");
    }
}
