//! Typed metric instruments: counters, gauges, and log-bucketed bounded
//! histograms, plus a named registry the exporter can snapshot.
//!
//! The histogram replaces the unbounded `Vec<u128>` sample buffers the
//! coordinator's `Metrics` used to keep: memory is a fixed ~15 KiB per
//! histogram regardless of how many samples are recorded. Bucketing is
//! exact for values `0..=1024` (one bucket per microsecond — this keeps
//! the serving stack's sub-millisecond unit-test fixtures bit-exact) and
//! logarithmic above with 16 linear sub-buckets per power of two, so any
//! percentile estimate is off by at most one bucket width, i.e. at most
//! `1/16` (6.25%) of the true value.
//!
//! Percentile convention matches `util::bench::percentile_us`:
//! `sorted[min(floor(n*p), n-1)]`, 0 on empty.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Monotonic counter.
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (queue depth, pool bytes, ticket occupancy...).
#[derive(Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Values `0..=LINEAR_MAX` get one exact bucket each.
const LINEAR_MAX: u64 = 1024;
/// log2(sub-buckets per octave) above the linear range.
const SUB_BITS: u32 = 4;
const SUBS: usize = 1 << SUB_BITS;
/// First log octave: values in `(LINEAR_MAX, 2^(E0+1))` land in octave E0.
const E0: u32 = 10; // 2^10 = LINEAR_MAX
const N_BUCKETS: usize = (LINEAR_MAX as usize + 1) + (64 - E0 as usize) * SUBS;

/// Bounded log-bucketed histogram over `u64` samples (microseconds in
/// every current use). Lock-free recording, O(1) memory.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let buckets: Vec<AtomicU64> = (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            buckets: buckets.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    fn index(v: u64) -> usize {
        if v <= LINEAR_MAX {
            return v as usize;
        }
        let e = 63 - v.leading_zeros(); // >= E0
        let sub = ((v >> (e - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
        (LINEAR_MAX as usize + 1) + (e - E0) as usize * SUBS + sub
    }

    /// `[lo, hi)` value range of bucket `idx` (hi saturates at u64::MAX).
    fn bounds(idx: usize) -> (u64, u64) {
        if idx <= LINEAR_MAX as usize {
            return (idx as u64, idx as u64 + 1);
        }
        let k = idx - (LINEAR_MAX as usize + 1);
        let e = E0 + (k / SUBS) as u32;
        let sub = (k % SUBS) as u64;
        let width = 1u64 << (e - SUB_BITS);
        let lo = (1u64 << e) + sub * width;
        let hi = (lo as u128 + width as u128).min(u64::MAX as u128) as u64;
        (lo, hi)
    }

    /// Representative value reported for samples in bucket `idx`: the
    /// exact value in the linear range, the bucket midpoint above it.
    fn representative(idx: usize) -> u64 {
        let (lo, hi) = Self::bounds(idx);
        if idx <= LINEAR_MAX as usize {
            lo
        } else {
            (((lo as u128) + (hi as u128)) / 2) as u64
        }
    }

    /// Width of the bucket containing `v` — the error bound for any
    /// percentile estimate whose exact value is `v`.
    pub fn error_bound(v: u64) -> u64 {
        let (lo, hi) = Self::bounds(Self::index(v));
        hi - lo
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum() as f64 / n as f64
    }

    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX && self.count() == 0 {
            0
        } else {
            m
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Percentile estimate, `sorted[min(floor(n*p), n-1)]` convention.
    /// Exact for samples `<= 1024`; within one bucket width above.
    pub fn percentile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((n as f64 * p) as u64).min(n - 1);
        let mut cum = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum > rank {
                return Self::representative(idx).min(self.max()).max(self.min());
            }
        }
        self.max()
    }

    fn snapshot_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("sum", Json::num(self.sum() as f64)),
            ("min", Json::num(self.min() as f64)),
            ("max", Json::num(self.max() as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.percentile(0.50) as f64)),
            ("p90", Json::num(self.percentile(0.90) as f64)),
            ("p99", Json::num(self.percentile(0.99) as f64)),
        ])
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<&'static str, Arc<Counter>>,
    gauges: BTreeMap<&'static str, Arc<Gauge>>,
    histograms: BTreeMap<&'static str, Arc<Histogram>>,
}

/// Named instrument registry. `counter`/`gauge`/`histogram` get-or-create
/// by name and hand back an `Arc` handle, so hot paths record lock-free
/// and only registration/snapshot take the map lock.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        Arc::clone(
            self.inner.lock().unwrap().counters.entry(name).or_insert_with(Arc::default),
        )
    }

    pub fn gauge(&self, name: &'static str) -> Arc<Gauge> {
        Arc::clone(self.inner.lock().unwrap().gauges.entry(name).or_insert_with(Arc::default))
    }

    pub fn histogram(&self, name: &'static str) -> Arc<Histogram> {
        Arc::clone(
            self.inner.lock().unwrap().histograms.entry(name).or_insert_with(Arc::default),
        )
    }

    /// One JSON object per instrument kind — the exporter appends this
    /// (plus a timestamp) as a JSONL metrics snapshot line.
    pub fn snapshot_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters: Vec<(&str, Json)> =
            g.counters.iter().map(|(k, c)| (*k, Json::num(c.get() as f64))).collect();
        let gauges: Vec<(&str, Json)> =
            g.gauges.iter().map(|(k, c)| (*k, Json::num(c.get() as f64))).collect();
        let hists: Vec<(&str, Json)> =
            g.histograms.iter().map(|(k, h)| (*k, h.snapshot_json())).collect();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::percentile_us;
    use crate::util::rng::Rng;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        assert_eq!(g.get(), 0);
        g.set(17);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn bucket_edges_are_lossless_or_bounded() {
        // 0, powers of two, u64::MAX: index→bounds must contain the value
        // and representative must stay within the bucket.
        let mut edges: Vec<u64> = vec![0, 1, 2, LINEAR_MAX, LINEAR_MAX + 1, u64::MAX];
        for e in 0..64u32 {
            let p = 1u64 << e;
            edges.extend([p.saturating_sub(1), p, p.saturating_add(1)]);
        }
        for &v in &edges {
            let idx = Histogram::index(v);
            assert!(idx < N_BUCKETS, "index in range for {v}");
            let (lo, hi) = Histogram::bounds(idx);
            assert!(lo <= v, "lo {lo} <= v {v}");
            assert!(v < hi || hi == u64::MAX, "v {v} < hi {hi}");
            let rep = Histogram::representative(idx);
            assert!(lo <= rep && (rep < hi || hi == u64::MAX), "rep inside bucket for {v}");
            if v <= LINEAR_MAX {
                assert_eq!(rep, v, "linear range is exact");
            } else {
                let width = hi - lo;
                assert!(width <= lo / 8, "relative width {width}/{lo} bounded for {v}");
            }
        }
    }

    #[test]
    fn single_extreme_samples_round_trip() {
        for v in [0u64, 1, LINEAR_MAX, u64::MAX] {
            let h = Histogram::new();
            h.record(v);
            assert_eq!(h.count(), 1);
            assert_eq!(h.min(), v);
            assert_eq!(h.max(), v);
            // min/max clamping means a lone sample reports exactly.
            assert_eq!(h.percentile(0.5), v, "single-sample percentile exact for {v}");
        }
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert!(h.mean().abs() < 1e-12);
    }

    #[test]
    fn small_values_match_exact_percentiles() {
        let h = Histogram::new();
        let samples: Vec<u128> = (1..=100).collect();
        for &s in &samples {
            h.record(s as u64);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for p in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(
                h.percentile(p),
                percentile_us(&sorted, p) as u64,
                "exact below LINEAR_MAX at p={p}"
            );
        }
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn property_percentiles_within_one_bucket_of_exact() {
        // Satellite: random workloads spanning the log range — histogram
        // percentile must sit within one bucket width of the exact
        // sorted-Vec percentile (the pre-migration Metrics behavior).
        let mut rng = Rng::new(0x0b5_0b5);
        for case in 0..50 {
            let n = 1 + (rng.next_u64() % 400) as usize;
            let h = Histogram::new();
            let mut vals: Vec<u128> = Vec::with_capacity(n);
            for _ in 0..n {
                // log-uniform-ish: pick an exponent, then jitter within it
                let e = rng.next_u64() % 40;
                let v = (1u64 << e) + rng.next_u64() % (1u64 << e).max(1);
                vals.push(v as u128);
                h.record(v);
            }
            vals.sort_unstable();
            for p in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
                let exact = percentile_us(&vals, p) as u64;
                let est = h.percentile(p);
                let tol = Histogram::error_bound(exact);
                let diff = est.abs_diff(exact);
                assert!(
                    diff <= tol,
                    "case {case} p={p}: est {est} vs exact {exact}, |diff| {diff} > bucket {tol}"
                );
            }
            assert_eq!(h.count(), n as u64);
        }
    }

    #[test]
    fn registry_reuses_instruments_by_name() {
        let r = Registry::new();
        let a = r.counter("reqs");
        let b = r.counter("reqs");
        a.inc();
        b.inc();
        assert_eq!(r.counter("reqs").get(), 2, "same name = same instrument");
        r.gauge("depth").set(7);
        r.histogram("lat").record(30);
        let snap = format!("{}", r.snapshot_json());
        assert!(snap.contains("\"reqs\":2"));
        assert!(snap.contains("\"depth\":7"));
        assert!(snap.contains("\"lat\""));
        assert!(snap.contains("\"p50\":30"));
    }
}
