#!/usr/bin/env python3
"""Render results/*.jsonl into the markdown tables EXPERIMENTS.md embeds.

Usage: python3 scripts/summarize_results.py [results_dir] [--check]

--check turns the run into a bench-regression gate: after printing, it
asserts the blocked kernel still beats the scalar path in keys/sec at
>=4k context (from attention.jsonl's "kernel" records) and exits
non-zero otherwise — CI's bench-smoke step runs it on every push.
"""

import json
import sys
from collections import defaultdict
from pathlib import Path

ARGS = [a for a in sys.argv[1:] if a != "--check"]
CHECK = "--check" in sys.argv[1:]
RES = Path(ARGS[0] if ARGS else "results")

METHODS = ["Baseline", "HAD (ours)", "BiT", "w/ SAB", "w/o AD", "w/o Tanh"]


def rows(name):
    """Records from results/<name>.jsonl, restricted to the latest run.

    Bench mains append, so a results file accumulates records across
    invocations. Every record since schema v2 carries a process-stable
    "run" id; only the run of the LAST record (the newest append) is
    summarized, and a note labels it. Pre-v2 records have no run id and
    are treated as one legacy run.
    """
    path = RES / f"{name}.jsonl"
    if not path.exists():
        return []
    recs = [json.loads(line) for line in path.read_text().splitlines() if line.strip()]
    if not recs:
        return recs
    run = recs[-1].get("run")
    kept = [r for r in recs if r.get("run") == run]
    ignored = len(recs) - len(kept)
    older = {r.get("run") for r in recs} - {run}
    label = run if run is not None else "(pre-schema-v2 records, no run id)"
    sha = kept[-1].get("git_sha")
    note = f"[run] {name}.jsonl: summarizing {label}" + (f" @ {sha}" if sha else "")
    if ignored:
        note += f"; ignoring {ignored} record(s) from {len(older)} older run(s)"
    print(note, file=sys.stderr)
    return kept


def table1():
    recs = rows("table1")
    if not recs:
        return
    by_task = defaultdict(dict)
    for r in recs:
        by_task[r["task"]][r["method"]] = r["value"]  # last write wins
    print("\n### Table 1 (measured)\n")
    print("| Task | " + " | ".join(METHODS) + " |")
    print("|" + "---|" * (len(METHODS) + 1))
    sums = defaultdict(float)
    n = 0
    for task, vals in by_task.items():
        cells = [f"{vals.get(m, float('nan')):.2f}" for m in METHODS]
        print(f"| {task} | " + " | ".join(cells) + " |")
        for m in METHODS:
            sums[m] += vals.get(m, 0.0)
        n += 1
    if n:
        print("| **Avg** | " + " | ".join(f"{sums[m]/n:.2f}" for m in METHODS) + " |")


def table2():
    recs = rows("table2")
    if not recs:
        return
    by_cfg = defaultdict(dict)
    for r in recs:
        by_cfg[r["config"]][r["method"]] = r["accuracy"]
    print("\n### Table 2 (measured)\n")
    cfgs = list(by_cfg)
    print("| Method | " + " | ".join(cfgs) + " |")
    print("|" + "---|" * (len(cfgs) + 1))
    for m in METHODS:
        cells = [f"{by_cfg[c].get(m, float('nan')):.2f}" for c in cfgs]
        print(f"| {m} | " + " | ".join(cells) + " |")


def fig(name, cols):
    recs = rows(name)
    if not recs:
        return
    print(f"\n### {name} (measured)\n")
    print("| " + " | ".join(cols) + " |")
    print("|" + "---|" * len(cols))
    for r in recs:
        cells = []
        for c in cols:
            v = r.get(c)
            if isinstance(v, float):
                cells.append(f"{v:.3f}")
            elif isinstance(v, list):
                cells.append("/".join(f"{x:.3f}" for x in v))
            else:
                cells.append(str(v))
        print("| " + " | ".join(cells) + " |")


def attention():
    recs = rows("attention")
    if not recs:
        return
    kern = [r for r in recs if r.get("kind") == "kernel"]
    by_ctx = defaultdict(dict)
    for r in kern:
        by_ctx[int(r["n_k"])][r["variant"]] = r  # last write wins
    want = {"scalar", "blocked", "threaded", "standard"}
    if by_ctx:
        print("\n### Attention kernel: scalar vs blocked vs blocked+threaded (measured)\n")
        print(
            "| n_k | f32 standard (µs) | scalar (µs) | blocked (µs) | threaded (µs) "
            "| blocked keys/s | blocked vs scalar | threaded vs f32 |"
        )
        print("|---|---|---|---|---|---|---|---|")
        for n_ctx in sorted(by_ctx):
            m = by_ctx[n_ctx]
            if want <= m.keys():
                st, sc, bl, th = (m[v] for v in ("standard", "scalar", "blocked", "threaded"))
                vs_scalar = sc["mean_us"] / bl["mean_us"] if bl["mean_us"] else float("nan")
                print(
                    f"| {n_ctx} | {st['mean_us']:.1f} | {sc['mean_us']:.1f} "
                    f"| {bl['mean_us']:.1f} | {th['mean_us']:.1f} "
                    f"| {bl['keys_per_s']:.3g} | {vs_scalar:.2f}x "
                    f"| {th['speedup_vs_standard']:.1f}x |"
                )
    scaling = [r for r in recs if r.get("kind") == "scaling"]
    by_workers = defaultdict(dict)
    for r in scaling:
        by_workers[int(r["n_k"])][int(r["workers"])] = r["speedup_vs_serial"]
    if by_workers:
        workers = sorted({w for m in by_workers.values() for w in m})
        print("\nThreaded scaling (speedup vs serial blocked kernel):\n")
        print("| n_k | " + " | ".join(f"{w} workers" for w in workers) + " |")
        print("|" + "---|" * (len(workers) + 1))
        for n_ctx in sorted(by_workers):
            cells = [
                f"{by_workers[n_ctx].get(w, float('nan')):.2f}x" for w in workers
            ]
            print(f"| {n_ctx} | " + " | ".join(cells) + " |")
    backends(recs)


def backends(recs):
    """Per-backend speedup table from the bench's popcount backend sweep,
    keyed by (head dim, context length) — the sweep covers W=1 tiles,
    the widest monomorphized tiles, and the dyn wide-head path."""
    be = [r for r in recs if r.get("kind") == "backend"]
    if not be:
        return
    by_shape = defaultdict(dict)
    names = []
    for r in be:
        if r["backend"] not in names:
            names.append(r["backend"])
        by_shape[(int(r.get("d", 64)), int(r["n_k"]))][r["backend"]] = r  # last write wins
    print("\n### Popcount backends: speedup vs the scalar oracle (measured)\n")
    print("| d | n_k | " + " | ".join(names) + " |")
    print("|" + "---|" * (len(names) + 2))
    for (dim, n_ctx) in sorted(by_shape):
        cells = []
        for name in names:
            r = by_shape[(dim, n_ctx)].get(name)
            if r is None:
                cells.append("—")
            else:
                cells.append(f"{r['mean_us']:.1f} µs ({r['speedup_vs_scalar']:.2f}x)")
        print(f"| {dim} | {n_ctx} | " + " | ".join(cells) + " |")
    last = be[-1]
    active = [r["backend"] for r in be if r.get("active")]
    print(
        f"\nhost: {last.get('cpu_features', '?')}"
        + (f" | active backend: {active[-1]}" if active else "")
    )


def best_keys_per_s(r):
    """Best-observed throughput: min-time based when the record carries
    min_us (noise-robust under the CI smoke step's tiny quick-mode
    budgets — a single scheduling stall inflates a mean but not a
    minimum), mean-based keys_per_s otherwise (older records)."""
    if r.get("min_us"):
        return (r["n_q"] * r["n_k"]) / (r["min_us"] / 1e6)
    return r["keys_per_s"]


def check_attention_gate():
    """--check: the blocked kernel must beat scalar keys/sec at >=4k context.

    Reads attention.jsonl "kernel" records (last write per (n_k, variant)
    wins), comparing best-observed throughput per variant. Failing — or
    having nothing to check — exits non-zero, so a silent bench
    regression or a bench that stopped emitting records both trip CI.
    """
    recs = rows("attention")
    pairs = defaultdict(dict)
    for r in recs:
        if r.get("kind") == "kernel" and int(r["n_k"]) >= 4096:
            pairs[int(r["n_k"])][r["variant"]] = r
    checked, failures = 0, []
    for n_k in sorted(pairs):
        m = pairs[n_k]
        if {"scalar", "blocked"} <= m.keys():
            checked += 1
            sc = best_keys_per_s(m["scalar"])
            bl = best_keys_per_s(m["blocked"])
            if bl <= sc:
                failures.append(
                    f"n_k={n_k}: blocked {bl:.3g} keys/s <= scalar {sc:.3g} keys/s (best-observed)"
                )
    if checked == 0:
        print("[check] FAIL: no >=4k-context kernel records in attention.jsonl")
        sys.exit(1)
    if failures:
        print("[check] FAIL: blocked kernel regressed below the scalar path:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print(
        f"[check] OK: blocked kernel beats scalar keys/sec at >=4k context "
        f"({checked} bucket(s) checked)"
    )


def kvcache():
    recs = rows("kvcache")
    if not recs:
        return
    lat = [r for r in recs if r.get("kind") == "latency"]
    by_ctx = defaultdict(dict)
    for r in lat:
        by_ctx[int(r["n_ctx"])][r["mode"]] = r  # last write wins
    if by_ctx:
        print("\n### KV cache: warm incremental append vs cold full prefill (measured)\n")
        print("| n_ctx | cold p50 (µs) | cold p99 (µs) | warm p50 (µs) | warm p99 (µs) | speedup (mean) |")
        print("|---|---|---|---|---|---|")
        for n_ctx in sorted(by_ctx):
            m = by_ctx[n_ctx]
            if {"cold", "warm"} <= m.keys():
                c, w = m["cold"], m["warm"]
                speed = c["mean_us"] / w["mean_us"] if w["mean_us"] else float("nan")
                print(
                    f"| {n_ctx} | {c['p50_us']:.1f} | {c['p99_us']:.1f} "
                    f"| {w['p50_us']:.1f} | {w['p99_us']:.1f} | {speed:.2f}x |"
                )
    pools = [r for r in recs if r.get("kind") == "pool"]
    if pools:
        p = pools[-1]
        print(
            f"\nKV pool: hit rate {100 * p['hit_rate']:.1f}% "
            f"({int(p['hits'])} hits / {int(p['misses'])} misses), "
            f"{int(p['evictions'])} evictions, "
            f"{int(p['resident_bytes']) // 1024} KiB resident"
        )


def serve():
    recs = rows("serve")
    if not recs:
        return
    dec = [r for r in recs if r.get("kind") == "decode"]
    by_ctx = defaultdict(dict)
    for r in dec:
        by_ctx[int(r["n_ctx"])][r["mode"]] = r  # last write wins
    if by_ctx:
        print("\n### Serving backend: cold prefill vs warm suffix decode (measured)\n")
        print(
            "| n_ctx | prefill tok/s | prefill kernel share | warm-turn tok/s "
            "| warm kernel share | turn vs prefill |"
        )
        print("|---|---|---|---|---|---|")
        for n_ctx in sorted(by_ctx):
            m = by_ctx[n_ctx]
            if {"prefill", "turn"} <= m.keys():
                p, t = m["prefill"], m["turn"]
                ratio = p["mean_us"] / t["mean_us"] if t["mean_us"] else float("nan")
                print(
                    f"| {n_ctx} | {p['tokens_per_s']:.3g} | {100 * p['kernel_share']:.1f}% "
                    f"| {t['tokens_per_s']:.3g} | {100 * t['kernel_share']:.1f}% "
                    f"| {ratio:.2f}x |"
                )
    sess = [r for r in recs if r.get("kind") == "sessions"]
    if sess:
        s = sess[-1]
        print(
            f"\nSession serving: {int(s['requests'])} requests, "
            f"hit rate {100 * s['hit_rate']:.1f}%, "
            f"latency p50 {s['p50_us'] / 1e3:.2f} ms / p99 {s['p99_us'] / 1e3:.2f} ms, "
            f"decode mean {s['decode_mean_us'] / 1e3:.2f} ms "
            f"(kernel share {100 * s['kernel_share']:.1f}%)"
        )


def generate():
    recs = rows("generate")
    if not recs:
        return
    eng = [r for r in recs if r.get("kind") == "engine"]
    if eng:
        e = eng[-1]
        print("\n### Generation: direct engine loop (measured)\n")
        print(
            f"prompt {int(e['prompt_len'])} + {int(e['new_tokens'])} greedy tokens: "
            f"ttft {e['ttft_us'] / 1e3:.2f} ms, "
            f"inter-token p50 {e['inter_p50_us'] / 1e3:.2f} ms / "
            f"p99 {e['inter_p99_us'] / 1e3:.2f} ms, "
            f"{e['tokens_per_s']:.1f} tok/s"
        )
    streams = [r for r in recs if r.get("kind") == "streams"]
    by_n = {}
    for r in streams:
        by_n[int(r["streams"])] = r  # last write wins
    if by_n:
        print("\n### Generation: continuous batching vs concurrency (measured)\n")
        print(
            "| streams | ttft p50 (ms) | ttft p99 (ms) | inter-token p50 (ms) "
            "| inter-token p99 (ms) | tok/s |"
        )
        print("|---|---|---|---|---|---|")
        for n in sorted(by_n):
            r = by_n[n]
            print(
                f"| {n} | {r['ttft_p50_us'] / 1e3:.2f} | {r['ttft_p99_us'] / 1e3:.2f} "
                f"| {r['inter_p50_us'] / 1e3:.2f} | {r['inter_p99_us'] / 1e3:.2f} "
                f"| {r['tokens_per_s']:.1f} |"
            )


def trace_attribution():
    """Per-stage time-attribution table from results/trace/trace.json
    (written by a bench run under HAD_TRACE=results/trace)."""
    path = RES / "trace" / "trace.json"
    if not path.exists():
        return
    try:
        trace = json.loads(path.read_text())
        events = trace["traceEvents"]
    except (json.JSONDecodeError, KeyError) as e:
        print(f"\n(trace present but unreadable: {e})")
        return
    spans = [e for e in events if e.get("ph") == "X"]
    if not spans:
        return
    wall_us = max(e["ts"] + e["dur"] for e in spans) - min(e["ts"] for e in spans)
    by_stage = defaultdict(lambda: [0, 0.0])  # name -> [count, total µs]
    for e in spans:
        agg = by_stage[e.get("name", "?")]
        agg[0] += 1
        agg[1] += e["dur"]
    print("\n### Trace: per-stage time attribution (measured)\n")
    print(f"{len(spans)} spans over {wall_us / 1e3:.1f} ms of traced wall time")
    print("(umbrella spans — request/stream/tick/decode — overlap their children)\n")
    print("| stage | spans | total (ms) | share of wall |")
    print("|---|---|---|---|")
    for name, (count, total) in sorted(by_stage.items(), key=lambda kv: -kv[1][1]):
        share = 100.0 * total / wall_us if wall_us else float("nan")
        print(f"| {name} | {count} | {total / 1e3:.2f} | {share:.1f}% |")
    meta = next((e for e in events if e.get("name") == "trace_meta"), None)
    if meta:
        dropped = meta.get("args", {}).get("dropped_spans", 0)
        if dropped:
            print(f"\n({dropped} span(s) dropped to ring wraparound — attribution is partial)")


if __name__ == "__main__":
    table1()
    table2()
    fig("fig1", ["n_ctx", "full_ms", "noattn_ms", "had_ms", "attn_share"])
    fig("fig3", ["n_top", "accuracy"])
    fig("fig4", ["n", "fractions"])
    fig("fig5", ["n_ctx", "n_top", "baseline", "had"])
    attention()
    kvcache()
    serve()
    generate()
    trace_attribution()
    t3 = rows("table3")
    if t3:
        r = t3[-1]
        print("\n### table3 (measured)\n")
        print(
            f"SA {r['sa_area_mm2']:.3f} mm² / {r['sa_power_w']:.3f} W ; "
            f"HAD {r['had_area_mm2']:.3f} mm² / {r['had_power_w']:.3f} W ; "
            f"reductions {100*(1-r['had_area_mm2']/r['sa_area_mm2']):.1f}% area, "
            f"{100*(1-r['had_power_w']/r['sa_power_w']):.1f}% power"
        )
    if CHECK:
        check_attention_gate()
