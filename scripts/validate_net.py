#!/usr/bin/env python3
"""Validate a net stress-harness results file (benches/net_stress.rs
writes results/net.jsonl): every record parses, carries the schema-v2
provenance stamp, and upholds the socket-level robustness invariants —
all admitted streams retired, zero leaked pool bytes, the deadlock
watchdog never fired, the seeded identity check held (streamed chunks
byte-identical to the direct engine), the chaos sweep actually injected
faults, and client-observed p99 TTFT on the burst scenario stays under
the gate. Also requires the core scenario set to be present, so a
harness that silently skipped a scenario fails loudly.

Usage: python3 scripts/validate_net.py results/net.jsonl [max_ttft_p99_us]

max_ttft_p99_us defaults to 5000000 (5 s — generous for shared CI
runners; the gate catches order-of-magnitude regressions like a lost
per-token flush, not scheduler jitter).

Exits non-zero (listing the problems) on any violation — CI's net-smoke
step runs it against the net.jsonl its loopback leg emitted. Importable:
`validate(path, max_ttft_p99_us=...)` returns the list of problems
(empty = ok).
"""

import json
import sys

REQUIRED_SCENARIOS = {
    "net_identity",
    "net_burst",
    "net_slow_reader",
    "net_disconnect_storm",
    "net_fault_sweep",
}
NUM_KEYS = ("admitted", "retired", "leaked_bytes", "ttft_p99_us", "net_requests")
DEFAULT_MAX_TTFT_P99_US = 5_000_000


def validate(path, max_ttft_p99_us=DEFAULT_MAX_TTFT_P99_US):
    problems = []
    try:
        with open(path) as f:
            lines = [l for l in f.read().splitlines() if l.strip()]
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    if not lines:
        return [f"{path}: empty results file"]
    seen = set()
    for i, line in enumerate(lines):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append(f"record {i}: not valid JSON: {e}")
            continue
        if rec.get("kind") != "net":
            continue
        name = rec.get("name")
        if not isinstance(name, str):
            problems.append(f"record {i}: missing scenario name")
            continue
        seen.add(name)
        for key in NUM_KEYS:
            if not isinstance(rec.get(key), (int, float)):
                problems.append(f"record {i} ({name}): bad/missing {key}")
        if rec.get("retired") != rec.get("admitted"):
            problems.append(
                f"record {i} ({name}): {rec.get('admitted')} admitted but "
                f"{rec.get('retired')} retired — a stream vanished without a StopReason"
            )
        if rec.get("leaked_bytes", 0) != 0:
            problems.append(
                f"record {i} ({name}): {rec.get('leaked_bytes')} B still in the "
                "page pool after every session ended"
            )
        if rec.get("watchdog_ok") is not True:
            problems.append(f"record {i} ({name}): watchdog fired (deadlock)")
        for key in ("run", "git_sha", "schema"):
            if key not in rec:
                problems.append(f"record {i} ({name}): missing provenance key {key}")
        if name == "net_identity" and rec.get("identity_ok") is not True:
            problems.append(
                f"record {i} ({name}): socket stream diverged from the direct engine"
            )
        if name == "net_fault_sweep" and rec.get("faults_injected", 0) <= 0:
            problems.append(f"record {i} ({name}): seeded fault plan never fired")
        if name == "net_slow_reader" and rec.get("net_slow_writes", 0) <= 0:
            problems.append(
                f"record {i} ({name}): injected net_write stall never surfaced "
                "in the slow-write counter"
            )
        if name == "net_burst":
            ttft = rec.get("ttft_p99_us")
            if isinstance(ttft, (int, float)) and ttft > max_ttft_p99_us:
                problems.append(
                    f"record {i} ({name}): client-observed p99 TTFT {ttft:.0f} us "
                    f"exceeds the {max_ttft_p99_us:.0f} us gate"
                )
    missing = REQUIRED_SCENARIOS - seen
    if missing:
        problems.append(f"{path}: missing scenarios: {', '.join(sorted(missing))}")
    return problems


def main(argv):
    if len(argv) not in (2, 3):
        print(__doc__.strip(), file=sys.stderr)
        return 2
    max_ttft = DEFAULT_MAX_TTFT_P99_US
    if len(argv) == 3:
        try:
            max_ttft = float(argv[2])
        except ValueError:
            print(f"bad max_ttft_p99_us: {argv[2]!r}", file=sys.stderr)
            return 2
    problems = validate(argv[1], max_ttft_p99_us=max_ttft)
    if problems:
        print(f"[net] FAIL: {argv[1]}")
        for p in problems:
            print(f"  {p}")
        return 1
    with open(argv[1]) as f:
        n = sum(1 for l in f if l.strip() and json.loads(l).get("kind") == "net")
    print(f"[net] OK: {argv[1]} ({n} scenario records)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
