#!/usr/bin/env python3
"""Validate a Chrome-trace-event JSON file (the `HAD_TRACE` exporter's
trace.json): parses as JSON, has the trace-event envelope, and every
event carries the keys Perfetto / chrome://tracing need to render it.

Usage: python3 scripts/validate_trace.py results/trace/trace.json

Exits non-zero (listing the problems) on an invalid trace — CI's
bench-smoke step runs it against the trace its HAD_TRACE leg emitted.
Importable: `validate(path)` returns the list of problems (empty = ok).
"""

import json
import sys

# keys every complete ("X") span event must carry, with their types
SPAN_KEYS = {"name": str, "ph": str, "pid": int, "tid": int, "ts": int, "dur": int}


def validate(path):
    problems = []
    try:
        with open(path) as f:
            trace = json.load(f)
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    except json.JSONDecodeError as e:
        return [f"{path} is not valid JSON: {e}"]
    if not isinstance(trace, dict):
        return [f"{path}: top level must be an object, got {type(trace).__name__}"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return [f"{path}: missing traceEvents array"]
    n_spans = 0
    ids = set()
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        if ph == "M":  # metadata events only need name/ph
            if not isinstance(e.get("name"), str):
                problems.append(f"event {i}: metadata event without a name")
            continue
        if ph != "X":
            problems.append(f"event {i}: unexpected phase {ph!r} (exporter emits X and M)")
            continue
        n_spans += 1
        for key, typ in SPAN_KEYS.items():
            if not isinstance(e.get(key), typ):
                problems.append(f"event {i} ({e.get('name')!r}): bad/missing {key}")
        if e.get("dur", 0) < 0 or e.get("ts", 0) < 0:
            problems.append(f"event {i} ({e.get('name')!r}): negative ts/dur")
        args = e.get("args", {})
        if not isinstance(args, dict) or "id" not in args or "parent" not in args:
            problems.append(f"event {i} ({e.get('name')!r}): args must carry id and parent")
        else:
            ids.add(args["id"])
    # parent links must resolve (0 = root) — unless the recorder dropped
    # spans to ring wraparound, in which case missing parents are expected
    meta = next(
        (e for e in events if isinstance(e, dict) and e.get("name") == "trace_meta"), None
    )
    dropped = (meta or {}).get("args", {}).get("dropped_spans", 0)
    if not dropped:
        for i, e in enumerate(events):
            if isinstance(e, dict) and e.get("ph") == "X":
                parent = e.get("args", {}).get("parent")
                if parent not in (None, 0) and parent not in ids:
                    problems.append(
                        f"event {i} ({e.get('name')!r}): parent {parent} not in the trace"
                    )
    if n_spans == 0:
        problems.append(f"{path}: no span (ph=X) events")
    return problems


def main(argv):
    if len(argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    problems = validate(argv[1])
    if problems:
        print(f"[trace] FAIL: {argv[1]}")
        for p in problems:
            print(f"  {p}")
        return 1
    with open(argv[1]) as f:
        n = sum(1 for e in json.load(f)["traceEvents"] if e.get("ph") == "X")
    print(f"[trace] OK: {argv[1]} ({n} spans)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
