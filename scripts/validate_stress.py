#!/usr/bin/env python3
"""Validate a stress-harness results file (benches/stress.rs writes
results/stress.jsonl): every record parses, carries the schema-v2
provenance stamp, and upholds the robustness invariants — all admitted
streams retired, zero leaked pool bytes, and the deadlock watchdog never
fired. Also requires the core scenario set to be present, so a harness
that silently skipped a scenario fails loudly.

Usage: python3 scripts/validate_stress.py results/stress.jsonl

Exits non-zero (listing the problems) on any violation — CI's
chaos-smoke step runs it against the stress.jsonl its HAD_FAULT leg
emitted. Importable: `validate(path)` returns the list of problems
(empty = ok).
"""

import json
import sys

REQUIRED_SCENARIOS = {
    "burst",
    "longtail",
    "slow_reader",
    "disconnect_storm",
    "fault_sweep",
    "spill_chaos",
}
NUM_KEYS = ("admitted", "retired", "leaked_bytes")


def validate(path):
    problems = []
    try:
        with open(path) as f:
            lines = [l for l in f.read().splitlines() if l.strip()]
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    if not lines:
        return [f"{path}: empty results file"]
    seen = set()
    for i, line in enumerate(lines):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append(f"record {i}: not valid JSON: {e}")
            continue
        if rec.get("kind") != "stress":
            continue
        name = rec.get("name")
        if not isinstance(name, str):
            problems.append(f"record {i}: missing scenario name")
            continue
        seen.add(name)
        for key in NUM_KEYS:
            if not isinstance(rec.get(key), (int, float)):
                problems.append(f"record {i} ({name}): bad/missing {key}")
        if rec.get("retired") != rec.get("admitted"):
            problems.append(
                f"record {i} ({name}): {rec.get('admitted')} admitted but "
                f"{rec.get('retired')} retired — a stream vanished without a StopReason"
            )
        if rec.get("leaked_bytes", 0) != 0:
            problems.append(
                f"record {i} ({name}): {rec.get('leaked_bytes')} B still in the "
                "page pool after every session ended"
            )
        if rec.get("watchdog_ok") is not True:
            problems.append(f"record {i} ({name}): watchdog fired (deadlock)")
        for key in ("run", "git_sha", "schema"):
            if key not in rec:
                problems.append(f"record {i} ({name}): missing provenance key {key}")
        if name == "fault_sweep" and rec.get("faults_injected", 0) <= 0:
            problems.append(f"record {i} ({name}): seeded fault plan never fired")
        if name == "spill_chaos":
            engaged = rec.get("spill_writes", 0) + rec.get("spill_write_failures", 0)
            if engaged <= 0:
                problems.append(
                    f"record {i} ({name}): budget pressure never reached the spill tier"
                )
    missing = REQUIRED_SCENARIOS - seen
    if missing:
        problems.append(f"{path}: missing scenarios: {', '.join(sorted(missing))}")
    return problems


def main(argv):
    if len(argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    problems = validate(argv[1])
    if problems:
        print(f"[stress] FAIL: {argv[1]}")
        for p in problems:
            print(f"  {p}")
        return 1
    with open(argv[1]) as f:
        n = sum(1 for l in f if l.strip() and json.loads(l).get("kind") == "stress")
    print(f"[stress] OK: {argv[1]} ({n} scenario records)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
