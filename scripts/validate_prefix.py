#!/usr/bin/env python3
"""Validate a prefix-sharing results file (benches/prefix.rs writes
results/prefix.jsonl): every record parses, carries the schema
provenance stamp, and upholds the sharing invariants —

  * identity: tokens from the sharing-on run are bit-identical to the
    sharing-off baseline at every stream count;
  * prefill-once: with n streams over one identical prompt, the
    shareable prefix was prefilled exactly once — tokens_reused equals
    (n-1) * share_tokens, so no follower re-executed a shared stripe;
  * residency: at >1 stream the shared run holds strictly fewer pool
    bytes than the baseline (shared bytes counted once);
  * drain: the pool (private pages and shared registry) returned to
    zero bytes after every session ended.

Also requires the 1/4/16 stream-count sweep to be present, so a bench
that silently skipped a point fails loudly.

Usage: python3 scripts/validate_prefix.py results/prefix.jsonl

Exits non-zero (listing the problems) on any violation — CI's
prefix-smoke step runs it against the prefix.jsonl its bench leg
emitted. Importable: `validate(path)` returns the list of problems
(empty = ok).
"""

import json
import sys

REQUIRED_KINDS = {"streams"}
REQUIRED_STREAMS = {1, 4, 16}


def validate(path):
    problems = []
    try:
        with open(path) as f:
            lines = [l for l in f.read().splitlines() if l.strip()]
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    if not lines:
        return [f"{path}: empty results file"]
    seen_kinds = set()
    seen_streams = set()
    for i, line in enumerate(lines):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append(f"record {i}: not valid JSON: {e}")
            continue
        kind = rec.get("kind")
        if kind not in REQUIRED_KINDS:
            continue
        seen_kinds.add(kind)
        for key in ("run", "git_sha", "schema"):
            if key not in rec:
                problems.append(f"record {i} ({kind}): missing provenance key {key}")
        n = rec.get("streams")
        if not isinstance(n, (int, float)) or n < 1:
            problems.append(f"record {i} ({kind}): bad/missing streams")
            continue
        n = int(n)
        seen_streams.add(n)
        for key in ("baseline_ms", "sharing_ms", "tokens_reused", "expected_reuse"):
            if not isinstance(rec.get(key), (int, float)):
                problems.append(f"record {i} ({kind}): bad/missing {key}")
        if rec.get("identity_ok") is not True:
            problems.append(
                f"record {i} (streams={n}): identity_ok is not true — "
                "sharing changed a stream's tokens"
            )
        if rec.get("prefill_once") is not True:
            problems.append(
                f"record {i} (streams={n}): prefill_once is not true — "
                "the shared prompt prefix was not prefilled exactly once"
            )
        reused = rec.get("tokens_reused")
        expected = rec.get("expected_reuse")
        if (
            isinstance(reused, (int, float))
            and isinstance(expected, (int, float))
            and reused != expected
        ):
            problems.append(
                f"record {i} (streams={n}): reused {reused:.0f} prompt tokens, "
                f"expected exactly {expected:.0f}"
            )
        if n > 1 and rec.get("expected_reuse", 0) <= 0:
            problems.append(
                f"record {i} (streams={n}): expected_reuse is zero — "
                "the prompt had no shareable stripe, the sweep exercised nothing"
            )
        ratio = rec.get("bytes_ratio")
        if n > 1 and isinstance(ratio, (int, float)) and ratio >= 1.0:
            problems.append(
                f"record {i} (streams={n}): shared run resides {ratio:.2f}x the "
                "baseline bytes — shared pages were not deduplicated"
            )
        if rec.get("drained_ok") is not True:
            problems.append(
                f"record {i} (streams={n}): drained_ok is not true — "
                "pool bytes leaked after every session ended"
            )
    missing = REQUIRED_KINDS - seen_kinds
    if missing:
        problems.append(f"{path}: missing record kinds: {', '.join(sorted(missing))}")
    missing_streams = REQUIRED_STREAMS - seen_streams
    if missing_streams:
        problems.append(
            f"{path}: missing stream counts: "
            f"{', '.join(str(s) for s in sorted(missing_streams))}"
        )
    return problems


def main(argv):
    if len(argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    problems = validate(argv[1])
    if problems:
        print(f"[prefix] FAIL: {argv[1]}")
        for p in problems:
            print(f"  {p}")
        return 1
    with open(argv[1]) as f:
        n = sum(
            1
            for l in f
            if l.strip() and json.loads(l).get("kind") in REQUIRED_KINDS
        )
    print(f"[prefix] OK: {argv[1]} ({n} prefix records)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
