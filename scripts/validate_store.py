#!/usr/bin/env python3
"""Validate a persistent-store results file (benches/store.rs writes
results/store.jsonl): every record parses, carries the schema
provenance stamp, and upholds the store invariants —

  * checkpoint: mmap-loaded logits bit-identical to heap-loaded;
  * spill: a spilled-and-hydrated KV bit-identical to the original,
    zero checksum failures, and at >=4k context the hydrate path must
    beat re-prefilling the evicted tokens;
  * restart: a session forced to disk by budget pressure came back
    with bit-identical logits on its next turn, having actually
    spilled, with zero checksum failures.

Also requires all three record kinds to be present, so a bench that
silently skipped a part fails loudly.

Usage: python3 scripts/validate_store.py results/store.jsonl

Exits non-zero (listing the problems) on any violation — CI's
store-smoke step runs it against the store.jsonl its bench leg
emitted. Importable: `validate(path)` returns the list of problems
(empty = ok).
"""

import json
import sys

REQUIRED_KINDS = {"checkpoint", "spill", "restart"}
HYDRATE_GATE_CTX = 4096


def validate(path):
    problems = []
    try:
        with open(path) as f:
            lines = [l for l in f.read().splitlines() if l.strip()]
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    if not lines:
        return [f"{path}: empty results file"]
    seen = set()
    for i, line in enumerate(lines):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append(f"record {i}: not valid JSON: {e}")
            continue
        kind = rec.get("kind")
        if kind not in REQUIRED_KINDS:
            continue
        seen.add(kind)
        for key in ("run", "git_sha", "schema"):
            if key not in rec:
                problems.append(f"record {i} ({kind}): missing provenance key {key}")
        if rec.get("identity_ok") is not True:
            problems.append(
                f"record {i} ({kind}): identity_ok is not true — "
                "the store round-trip was not bit-identical"
            )
        if kind == "checkpoint":
            for key in ("cold_us", "mmap_us"):
                if not isinstance(rec.get(key), (int, float)):
                    problems.append(f"record {i} ({kind}): bad/missing {key}")
        if kind in ("spill", "restart") and rec.get("checksum_failures", 1) != 0:
            problems.append(
                f"record {i} ({kind}): {rec.get('checksum_failures')} store reads "
                "failed verification on a fault-free run"
            )
        if kind == "spill":
            for key in ("n_ctx", "hydrate_us", "reprefill_us"):
                if not isinstance(rec.get(key), (int, float)):
                    problems.append(f"record {i} ({kind}): bad/missing {key}")
            n_ctx = rec.get("n_ctx", 0)
            hydrate = rec.get("hydrate_us")
            reprefill = rec.get("reprefill_us")
            if (
                isinstance(n_ctx, (int, float))
                and n_ctx >= HYDRATE_GATE_CTX
                and isinstance(hydrate, (int, float))
                and isinstance(reprefill, (int, float))
                and hydrate >= reprefill
            ):
                problems.append(
                    f"record {i} ({kind}): at {n_ctx:.0f} context, hydrate "
                    f"({hydrate:.0f} us) must beat re-prefill ({reprefill:.0f} us)"
                )
        if kind == "restart" and rec.get("spill_pages_out", 0) <= 0:
            problems.append(
                f"record {i} ({kind}): budget pressure never spilled a page — "
                "the restart identity check exercised nothing"
            )
    missing = REQUIRED_KINDS - seen
    if missing:
        problems.append(f"{path}: missing record kinds: {', '.join(sorted(missing))}")
    return problems


def main(argv):
    if len(argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    problems = validate(argv[1])
    if problems:
        print(f"[store] FAIL: {argv[1]}")
        for p in problems:
            print(f"  {p}")
        return 1
    with open(argv[1]) as f:
        n = sum(
            1
            for l in f
            if l.strip() and json.loads(l).get("kind") in REQUIRED_KINDS
        )
    print(f"[store] OK: {argv[1]} ({n} store records)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
