#!/usr/bin/env python3
"""Offline validation of the serve-backend parity tests' seeds and
tolerances (rust/src/serve/reference.rs, rust/src/serve/engine.rs).

Mirrors the Rust stack closely enough to answer three questions the
fixed-seed Rust tests depend on but cannot answer about themselves:

1. **Sign margins** — decode and the f32 reference binarize the same
   continuous Q/K activations; they agree bit-for-bit on signs only if
   no activation sits within cross-implementation float noise (~1e-6) of
   zero. This script replays the exact seeds (the PRNG is mirrored
   word-for-word) and reports the minimum |q|/|k| margin at every
   binarization site.
2. **Design equivalence** — an independent float64 implementation of the
   decode-order algorithm and of the reference-order algorithm must
   agree to ~1e-9, catching semantic drift (causal window, temperature,
   top-N tie-breaks, position wrapping) rather than float-order noise.
3. **bf16 drift** — the engine test asserts bf16-valued caches move
   logits by < 0.05; this script measures the actual drift.

Run: python3 scripts/validate_serve_parity.py   (needs numpy)
"""

import math

import numpy as np

MASK = (1 << 64) - 1


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """Word-exact mirror of rust/src/util/rng.rs (SplitMix64 + xoshiro256**)."""

    def __init__(self, seed):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s = (s + 0x9E3779B97F4A7C15) & MASK
            z = s
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            self.s.append(z ^ (z >> 31))

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, bound):
        # Lemire multiply-shift rejection, as in rng.rs
        while True:
            x = self.next_u64()
            m = x * bound
            lo = m & MASK
            if lo >= bound:
                return m >> 64
            if lo >= (-bound) % (1 << 64) % bound:
                return m >> 64

    def normal(self):
        while True:
            u1 = self.next_f64()
            if u1 > 1e-12:
                u2 = self.next_f64()
                r = math.sqrt(-2.0 * math.log(u1))
                return np.float32(r * math.cos(2.0 * math.pi * u2))

    def normal_vec(self, n, std):
        return np.array(
            [self.normal() * np.float32(std) for _ in range(n)], dtype=np.float32
        )


# --- the serve_ref / engine test architecture ------------------------------

CFG = dict(n_layers=2, d_model=32, n_heads=2, d_ff=64, n_ctx=24,
           n_classes=3, vocab=24)


def param_specs(cfg):
    L, D, F = cfg["n_layers"], cfg["d_model"], cfg["d_ff"]
    specs = [("tok_emb", (cfg["vocab"], D), "n"), ("pos_emb", (cfg["n_ctx"], D), "n")]
    specs += [
        ("ln1_g", (L, D), "1"), ("ln1_b", (L, D), "0"),
        ("wq", (L, D, D), "n"), ("bq", (L, D), "0"),
        ("wk", (L, D, D), "n"), ("bk", (L, D), "0"),
        ("wv", (L, D, D), "n"), ("bv", (L, D), "0"),
        ("wo", (L, D, D), "n"), ("bo", (L, D), "0"),
        ("ln2_g", (L, D), "1"), ("ln2_b", (L, D), "0"),
        ("w1", (L, D, F), "n"), ("b1", (L, F), "0"),
        ("w2", (L, F, D), "n"), ("b2", (L, D), "0"),
        ("lnf_g", (D,), "1"), ("lnf_b", (D,), "0"),
        ("head_w", (D, cfg["n_classes"]), "n"), ("head_b", (cfg["n_classes"],), "0"),
    ]
    return specs


def init_params(cfg, seed):
    rng = Rng(seed)
    params = {}
    for name, shape, kind in param_specs(cfg):
        n = int(np.prod(shape))
        if kind == "n":
            params[name] = rng.normal_vec(n, 0.02).reshape(shape).astype(np.float64)
        elif kind == "0":
            params[name] = np.zeros(shape)
        else:
            params[name] = np.ones(shape)
    return params


def layernorm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) / np.sqrt(var + eps) * g + b


def gelu(x):
    c = 0.7978845608028654
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x**3)))


def sign(x):
    return np.where(x >= 0.0, 1.0, -1.0)


def topn_softmax(scores, n_top, scale):
    """Keep top n_top (ties: lowest index), softmax over kept * scale."""
    n = len(scores)
    k = min(max(n_top, 1), n)
    order = sorted(range(n), key=lambda j: (-scores[j], j))[:k]
    kept = np.array([scores[j] for j in order]) * scale
    e = np.exp(kept - kept.max())
    w = e / e.sum()
    out = np.zeros(n)
    for j, wj in zip(order, w):
        out[j] = wj
    return out


def _bf16(v, enabled):
    if not enabled:
        return v
    f32 = np.asarray(v, dtype=np.float32)
    as_int = f32.view(np.uint32)
    lsb = (as_int >> 16) & 1
    rounded = ((as_int + 0x7FFF + lsb) >> 16).astype(np.uint32) << 16
    return rounded.view(np.float32).astype(np.float64)


def reference_forward(params, cfg, tokens, n_top, margins=None, bf16_values=False):
    """Whole-sequence causal forward — mirrors serve/reference.rs."""
    L, D, H = cfg["n_layers"], cfg["d_model"], cfg["n_heads"]
    dh = D // H
    n = len(tokens)
    scale = 1.0 / math.sqrt(dh)  # temp = 1 (ServeModel::random)

    h = np.stack(
        [params["tok_emb"][tokens[p] % cfg["vocab"]]
         + params["pos_emb"][p % cfg["n_ctx"]] for p in range(n)]
    )
    for l in range(L):
        x = layernorm(h, params["ln1_g"][l], params["ln1_b"][l])
        q = x @ params["wq"][l] + params["bq"][l]
        k = x @ params["wk"][l] + params["bk"][l]
        v = x @ params["wv"][l] + params["bv"][l]
        if margins is not None:
            margins.append(np.abs(q).min())
            margins.append(np.abs(k).min())
        ctx = np.zeros_like(h)
        for head in range(H):
            cs = slice(head * dh, (head + 1) * dh)
            sq, sk = sign(q[:, cs]), sign(k[:, cs])
            vh = _bf16(v[:, cs], bf16_values)
            for i in range(n):
                scores = [float(sq[i] @ sk[j]) for j in range(i + 1)]
                w = topn_softmax(scores, n_top, scale)
                ctx[i, cs] = sum(w[j] * vh[j] for j in range(i + 1))
        h = h + ctx @ params["wo"][l] + params["bo"][l]
        y = layernorm(h, params["ln2_g"][l], params["ln2_b"][l])
        h = h + gelu(y @ params["w1"][l] + params["b1"][l]) @ params["w2"][l] + params["b2"][l]
    hf = layernorm(h, params["lnf_g"], params["lnf_b"])
    return hf @ params["head_w"] + params["head_b"]


def decode_forward(params, cfg, tokens, n_top, bf16_values=False):
    """Token-by-token decode with per-(layer, head) K/V caches — mirrors
    serve/engine.rs's loop structure (append THEN score, causal window of
    keys 0..=p, position wrap). Agreement with `reference_forward` to
    ~1e-9 in f64 validates the design (causality, temperature, top-N
    tie-break, wrapping), independent of float ordering."""
    L, D, H = cfg["n_layers"], cfg["d_model"], cfg["n_heads"]
    dh = D // H
    scale = 1.0 / math.sqrt(dh)
    keys = [[[] for _ in range(H)] for _ in range(L)]
    vals = [[[] for _ in range(H)] for _ in range(L)]
    outs = []
    for p, tok in enumerate(tokens):
        h = params["tok_emb"][tok % cfg["vocab"]] + params["pos_emb"][p % cfg["n_ctx"]]
        for l in range(L):
            x = layernorm(h[None, :], params["ln1_g"][l], params["ln1_b"][l])[0]
            q = x @ params["wq"][l] + params["bq"][l]
            k = x @ params["wk"][l] + params["bk"][l]
            v = x @ params["wv"][l] + params["bv"][l]
            ctx = np.zeros(D)
            for head in range(H):
                cs = slice(head * dh, (head + 1) * dh)
                keys[l][head].append(sign(k[cs]))
                vals[l][head].append(_bf16(v[cs], bf16_values))
                sq = sign(q[cs])
                scores = [float(sq @ kk) for kk in keys[l][head]]
                w = topn_softmax(scores, n_top, scale)
                ctx[cs] = sum(w[j] * vals[l][head][j] for j in range(len(scores)))
            h = h + ctx @ params["wo"][l] + params["bo"][l]
            y = layernorm(h[None, :], params["ln2_g"][l], params["ln2_b"][l])[0]
            h = h + gelu(y @ params["w1"][l] + params["b1"][l]) @ params["w2"][l] + params["b2"][l]
        hf = layernorm(h[None, :], params["lnf_g"], params["lnf_b"])[0]
        outs.append(hf @ params["head_w"] + params["head_b"])
    return np.stack(outs)


def check_case(name, seed, n_top, n_tokens, vocab):
    params = init_params(CFG, seed)
    toks_rng = Rng(seed ^ 0x5EED)
    tokens = [int(toks_rng.below(vocab)) for _ in range(n_tokens)]
    margins = []
    ref = reference_forward(params, CFG, tokens, n_top, margins=margins)
    dec = decode_forward(params, CFG, tokens, n_top)  # independent impl
    min_margin = min(margins)
    print(f"{name}: seed={seed} n_top={n_top} tokens={n_tokens}")
    print(f"  min |q|,|k| margin at binarization: {min_margin:.3e} "
          f"({'SAFE' if min_margin > 1e-4 else 'RISKY — pick another seed'})")
    print(f"  logits range: [{ref.min():+.3f}, {ref.max():+.3f}] "
          f"(1e-3 tolerance is {1e-3 / max(1e-9, np.abs(ref).max()):.1%} relative)")
    assert np.abs(ref - dec).max() < 1e-9
    return min_margin


def check_bf16(model_seed, tok_seed, n_top, n_tokens, vocab):
    params = init_params(CFG, model_seed)
    toks_rng = Rng(tok_seed)
    tokens = [int(toks_rng.below(vocab)) for _ in range(n_tokens)]
    a = decode_forward(params, CFG, tokens, n_top)
    b = decode_forward(params, CFG, tokens, n_top, bf16_values=True)
    drift = np.abs(a - b).max()
    print(f"bf16 drift: model_seed={model_seed} tok_seed={tok_seed}: "
          f"max logits diff {drift:.3e} "
          f"({'OK < 0.05' if drift < 0.05 else 'TOO LARGE'})")
    return drift


if __name__ == "__main__":
    # the two parity tests in serve/reference.rs
    m1 = check_case("dense parity", 35, 64, 18, CFG["vocab"])
    m2 = check_case("sparse parity", 23, 6, 18, CFG["vocab"])
    # engine.rs bf16_values_stay_close_to_f32 (model 0xA11CE, tokens
    # Rng::new(16), 12 tokens, n_top 6)
    d = check_bf16(0xA11CE, 16, 6, 12, 24)
    ok = m1 > 1e-4 and m2 > 1e-4 and 0.0 < d < 0.05
    print("\nVERDICT:", "all parity seeds/tolerances validated" if ok else "ADJUST SEEDS")
    raise SystemExit(0 if ok else 1)
