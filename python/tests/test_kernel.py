# pytest: kernel vs ref allclose — the CORE correctness signal.
"""Fused Pallas HAD attention vs the pure-jnp oracle.

hypothesis sweeps shapes, sparsity levels and input distributions; every
case asserts allclose against ref.had_attention_ref. Integer tie handling
(binary scores are massively tied) is exercised explicitly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.had_attention import had_attention, vmem_report


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def run_case(b, h, n, d, dv, n_top, block_q, key=0, temp=None):
    q = _rand(key, (b, h, n, d))
    k = _rand(key + 1, (b, h, n, d))
    v = _rand(key + 2, (b, h, n, dv))
    out = had_attention(q, k, v, n_top=n_top, block_q=block_q, temp=temp)
    d_scale = (1.0 if temp is None else float(temp)) / (d**0.5)
    want = ref.had_attention_ref(q, k, v, n_top, d_scale=d_scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_basic_shapes():
    run_case(2, 3, 64, 32, 16, 10, 32)


def test_single_block():
    run_case(1, 1, 16, 16, 16, 4, 16)


def test_n_top_full_context():
    # N == n degenerates to (binarized) dense attention.
    run_case(1, 2, 32, 16, 8, 32, 32)


def test_n_top_one():
    run_case(1, 2, 32, 16, 8, 1, 32)


def test_temp_scaling():
    run_case(1, 2, 32, 16, 8, 8, 32, temp=jnp.asarray(0.37))


def test_blockq_equals_n():
    run_case(2, 2, 64, 32, 32, 16, 64)


def test_indivisible_block_raises():
    q = _rand(0, (1, 1, 48, 16))
    with pytest.raises(ValueError):
        had_attention(q, q, q, n_top=4, block_q=32)


def test_dhead_exactness_guard():
    q = _rand(0, (1, 1, 8, 512))
    with pytest.raises(ValueError):
        had_attention(q, q, q, n_top=4, block_q=8)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    n_pow=st.integers(3, 6),          # n in {8..64}
    d=st.sampled_from([8, 16, 32, 64]),
    dv=st.sampled_from([8, 16, 32]),
    frac=st.floats(0.05, 1.0),
    key=st.integers(0, 2**16),
)
def test_hypothesis_sweep(b, h, n_pow, d, dv, frac, key):
    n = 2**n_pow
    n_top = max(1, int(frac * n))
    run_case(b, h, n, d, dv, n_top, block_q=n, key=key)


@settings(max_examples=10, deadline=None)
@given(key=st.integers(0, 2**16), scale=st.sampled_from([1e-3, 1.0, 1e3]))
def test_scale_invariance_of_pattern(key, scale):
    """Binarization is scale-invariant: outputs identical for scaled Q/K."""
    b, h, n, d, dv = 1, 2, 32, 16, 8
    q = _rand(key, (b, h, n, d))
    k = _rand(key + 1, (b, h, n, d))
    v = _rand(key + 2, (b, h, n, dv))
    o1 = had_attention(q, k, v, n_top=8, block_q=32)
    o2 = had_attention(q * scale, k * scale, v, n_top=8, block_q=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6, atol=1e-6)


def test_tied_scores_deterministic():
    """All-equal inputs => fully tied integer scores; kernel and oracle
    must agree on the tie-broken top-N selection."""
    b, h, n, d, dv = 1, 1, 16, 16, 8
    q = jnp.ones((b, h, n, d), jnp.float32)
    k = jnp.ones((b, h, n, d), jnp.float32)
    v = _rand(7, (b, h, n, dv))
    run_case_direct(q, k, v, n_top=4)


def run_case_direct(q, k, v, n_top):
    out = had_attention(q, k, v, n_top=n_top, block_q=q.shape[2])
    want = ref.had_attention_ref(q, k, v, n_top)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_output_rows_convex_combination():
    """Each output row is a convex combination of value rows: within the
    per-coordinate min/max envelope of V."""
    q = _rand(3, (1, 2, 32, 16))
    k = _rand(4, (1, 2, 32, 16))
    v = _rand(5, (1, 2, 32, 8))
    out = np.asarray(had_attention(q, k, v, n_top=8, block_q=32))
    vmin = np.asarray(v).min(axis=2, keepdims=True)
    vmax = np.asarray(v).max(axis=2, keepdims=True)
    assert (out >= vmin - 1e-5).all() and (out <= vmax + 1e-5).all()


def test_vmem_report_long_context():
    r = vmem_report(n_k=4096, d=64, d_v=64, block_q=128, n_top=120)
    assert r["fits_16MiB_vmem"]
    assert r["k_packed_bytes"] * 32 == r["k_bytes"]
