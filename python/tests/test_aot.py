"""AOT pipeline: manifest consistency and HLO text round-trip sanity."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import model, steps
from compile.aot import CONFIGS, artifact_plan, build_fn, to_hlo_text

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_configs_cover_all_experiments():
    names = set(CONFIGS)
    assert "tinyglue" in names          # Table 1
    assert {"vision_base", "vision_tiny"} <= names  # Table 2 + Fig 3
    assert {f"longqa_{n}" for n in (128, 256, 512, 1024)} <= names  # Fig 5 / Fig 1


def test_longqa_n_scales_linearly():
    """Paper §4.3: N 15 @ 128 ... 120 @ 1024 (constant sparsity fraction)."""
    for n in (128, 256, 512, 1024):
        cfg = CONFIGS[f"longqa_{n}"]["model"]
        assert cfg.n_top == 15 * n // 128


def test_artifact_plans_well_formed():
    for name in CONFIGS:
        plan = artifact_plan(name)
        names = [a["name"] for a in plan]
        assert len(names) == len(set(names))
        assert "teacher_step" in names and "calib" in names
        for art in plan:
            assert art["kind"] in ("teacher_step", "distill_step", "fwd", "calib")


def test_example_inputs_signature_lengths():
    cfg = CONFIGS["tinyglue"]["model"]
    n = len(model.param_specs(cfg))
    assert len(steps.example_inputs(cfg, "teacher_step", 4)) == 3 * n + 4
    assert len(steps.example_inputs(cfg, "distill_step", 4)) == 4 * n + 9
    assert len(steps.example_inputs(cfg, "fwd", 4)) == n + 4
    assert len(steps.example_inputs(cfg, "calib", 4)) == n + 1


def test_lower_one_artifact_to_hlo_text():
    cfg = CONFIGS["tinyglue"]["model"]
    art = {"kind": "fwd", "variant": "standard", "ste": True, "pallas": False, "batch": 2}
    text = to_hlo_text(build_fn(cfg, art), steps.example_inputs(cfg, "fwd", 2))
    assert text.startswith("HloModule")
    assert "ENTRY" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_files():
    with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    for art in manifest["artifacts"]:
        path = os.path.join(ARTIFACT_DIR, art["file"])
        assert os.path.exists(path), art["file"]
        assert art["config"] in manifest["configs"]
    for cname, centry in manifest["configs"].items():
        cfg = model.ModelConfig.from_dict(centry["model"])
        specs = model.param_specs(cfg)
        assert [p["name"] for p in centry["params"]] == [s[0] for s in specs]
        assert [tuple(p["shape"]) for p in centry["params"]] == [s[1] for s in specs]
