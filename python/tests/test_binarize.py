"""Binarization schedule primitives: stage limits, gradients, STE clipping."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import binarize


def test_hard_sign_zero_maps_to_plus_one():
    out = np.asarray(binarize.hard_sign(jnp.asarray([0.0, -0.0, 1.0, -1.0])))
    np.testing.assert_array_equal(out, [1.0, 1.0, 1.0, -1.0])


def test_ste_sign_forward_matches_hard_sign():
    x = jnp.linspace(-3, 3, 41)
    np.testing.assert_array_equal(
        np.asarray(binarize.ste_sign(x)), np.asarray(binarize.hard_sign(x))
    )


def test_ste_gradient_clipping():
    g = jax.grad(lambda x: jnp.sum(binarize.ste_sign(x)))(
        jnp.asarray([-2.0, -1.0, -0.5, 0.0, 0.5, 1.0, 2.0])
    )
    np.testing.assert_array_equal(np.asarray(g), [0, 1, 1, 1, 1, 1, 0])


def test_stage1_high_c_is_near_linear():
    """At c=5 the scaled tanh is close to identity for |x| << c*sigma."""
    x = jnp.linspace(-0.5, 0.5, 11)
    y = binarize.tanh_binarize(x, sigma=1.0, c=5.0, outer_mult=5.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=0, atol=5e-3)


def test_stage2_small_c_approaches_sign():
    x = jnp.asarray([-2.0, -0.3, 0.2, 1.5])
    y = binarize.tanh_binarize(x, sigma=1.0, c=0.01, outer_mult=1.0)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(binarize.hard_sign(x)), atol=1e-6
    )


def test_stage_boundary_continuity():
    """Stage 1 end (c=1, outer=c) == stage 2 start (c=1, outer=1)."""
    x = jnp.linspace(-2, 2, 17)
    s1 = binarize.tanh_binarize(x, sigma=0.7, c=1.0, outer_mult=1.0)
    s2 = binarize.tanh_binarize(x, sigma=0.7, c=1.0, outer_mult=1.0)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))


@settings(max_examples=20, deadline=None)
@given(
    sigma=st.floats(0.05, 10.0),
    key=st.integers(0, 2**16),
)
def test_ste_binarize_magnitude(sigma, key):
    """STE binarization outputs exactly ±sigma."""
    x = jax.random.normal(jax.random.PRNGKey(key), (64,), jnp.float32)
    y = np.asarray(binarize.ste_binarize(x, sigma))
    np.testing.assert_allclose(np.abs(y), sigma, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(c=st.floats(0.05, 5.0), sigma=st.floats(0.1, 5.0), key=st.integers(0, 2**10))
def test_tanh_binarize_bounded(c, sigma, key):
    """|tanh relaxation| <= outer_mult * sigma always."""
    x = 10.0 * jax.random.normal(jax.random.PRNGKey(key), (64,), jnp.float32)
    for outer in (c, 1.0):
        y = np.abs(np.asarray(binarize.tanh_binarize(x, sigma, c, outer)))
        assert (y <= outer * sigma + 1e-5).all()


def test_tanh_gradient_finite_and_nonzero():
    g = jax.grad(
        lambda x: jnp.sum(binarize.tanh_binarize(x, 1.0, 0.05, 1.0))
    )(jnp.asarray([0.0, 0.01, -0.01]))
    assert np.isfinite(np.asarray(g)).all()
    assert float(g[0]) > 0
