"""L2 model: shapes, variants, distillation losses, optimizer, param contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, optimizer, steps
from compile.model import ModelConfig

CFG_TOK = ModelConfig(
    n_layers=2, d_model=32, n_heads=2, d_ff=64,
    n_ctx=16, n_classes=4, vocab=64, n_top=5, block_q=16,
)
CFG_VIS = ModelConfig(
    n_layers=2, d_model=32, n_heads=2, d_ff=64,
    n_ctx=9, n_classes=8, vocab=0, input_dim=12, n_top=4, block_q=9,
)


def _params(cfg, seed=0):
    return model.init_params(cfg, jax.random.PRNGKey(seed))


def _tok_batch(cfg, b=4, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, cfg.n_ctx), 0, cfg.vocab)


def _vis_batch(cfg, b=4, seed=1):
    return jax.random.normal(
        jax.random.PRNGKey(seed), (b, cfg.n_patches, cfg.input_dim), jnp.float32
    )


def test_param_specs_roundtrip():
    p = _params(CFG_TOK)
    lst = model.params_to_list(CFG_TOK, p)
    p2 = model.params_from_list(CFG_TOK, lst)
    assert set(p) == set(p2)
    for k in p:
        np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(p2[k]))


def test_param_specs_shapes_match_init():
    for cfg in (CFG_TOK, CFG_VIS):
        p = _params(cfg)
        for name, shape, _ in model.param_specs(cfg):
            assert p[name].shape == shape, name


@pytest.mark.parametrize("variant", ["standard", "had", "bit", "sab", "fp_topn", "noattn"])
@pytest.mark.parametrize("cfg", [CFG_TOK, CFG_VIS], ids=["tok", "vis"])
def test_forward_shapes_all_variants(cfg, variant):
    p = _params(cfg)
    x = _tok_batch(cfg) if cfg.vocab else _vis_batch(cfg)
    logits = model.forward(p, x, cfg, variant, ste=True, n_top=float(cfg.n_top))
    assert logits.shape == (4, cfg.n_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_noattn_is_cheaper_graph():
    """noattn must not contain an n x n contraction (Figure-1 ablation)."""
    p = _params(CFG_TOK)
    x = _tok_batch(CFG_TOK)
    full = model.forward(p, x, CFG_TOK, "standard")
    no = model.forward(p, x, CFG_TOK, "noattn")
    # different computation, same interface
    assert full.shape == no.shape
    assert not np.allclose(np.asarray(full), np.asarray(no))


def test_had_forward_scale_invariance():
    """sign() makes the HAD student invariant to Q/K input scale at eval."""
    cfg = CFG_TOK
    p = _params(cfg)
    x = _tok_batch(cfg)
    base = model.forward(p, x, cfg, "had", ste=True, n_top=5.0)
    p2 = dict(p)
    p2["wq"] = p["wq"] * 3.0  # scales Q_c; sign(Q_c/sigma) unchanged per sign
    logits2 = model.forward(p2, x, cfg, "had", ste=True, n_top=5.0)
    np.testing.assert_allclose(np.asarray(base), np.asarray(logits2), rtol=1e-4, atol=1e-4)


def test_distill_forward_losses_nonnegative():
    cfg = CFG_TOK
    tp, sp = _params(cfg, 0), _params(cfg, 1)
    x = _tok_batch(cfg)
    z_s, z_t, kl_att = model.distill_forward(
        sp, tp, x, cfg, "had", ste=False, c=2.0, outer_mult=2.0,
        sigma_q=jnp.ones(2), sigma_k=jnp.ones(2), n_top=5.0,
    )
    kl_out = model.kl_output(z_t, z_s)
    assert float(kl_att) >= 0.0
    assert float(kl_out) >= 0.0


def test_distill_identical_student_zero_loss():
    """Student == teacher with near-linear binarization (huge c) => KL ~ 0."""
    cfg = CFG_TOK
    tp = _params(cfg, 0)
    x = _tok_batch(cfg)
    z_s, z_t, kl_att = model.distill_forward(
        tp, tp, x, cfg, "had", ste=False, c=1e4, outer_mult=1e4,
        sigma_q=jnp.ones(2), sigma_k=jnp.ones(2), n_top=float(cfg.n_ctx),
    )
    assert float(kl_att) < 1e-4
    assert float(model.kl_output(z_t, z_s)) < 1e-6


def test_kl_output_zero_iff_equal():
    z = jnp.asarray([[1.0, -2.0, 0.3]])
    assert float(model.kl_output(z, z)) == pytest.approx(0.0, abs=1e-7)
    assert float(model.kl_output(z, z + 1.0)) == pytest.approx(0.0, abs=1e-6)  # shift invariant
    assert float(model.kl_output(z, z * 2.0)) > 0.0


def test_qk_std_positive():
    cfg = CFG_TOK
    p = _params(cfg)
    sq, sk = model.qk_std(p, _tok_batch(cfg), cfg)
    assert sq.shape == (cfg.n_layers,) and sk.shape == (cfg.n_layers,)
    assert (np.asarray(sq) > 0).all() and (np.asarray(sk) > 0).all()


def test_adam_reduces_loss():
    cfg = CFG_TOK
    p = _params(cfg)
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    x = _tok_batch(cfg, 8)
    y = jax.random.randint(jax.random.PRNGKey(9), (8,), 0, cfg.n_classes)
    t = jnp.asarray(0.0)

    def loss_fn(p):
        return steps.cross_entropy(model.forward(p, x, cfg, "standard"), y)

    losses = []
    for _ in range(20):
        loss, g = jax.value_and_grad(loss_fn)(p)
        losses.append(float(loss))
        p, m, v, t = optimizer.adam_update(p, g, m, v, t, jnp.asarray(1e-2))
    assert losses[-1] < losses[0] * 0.8


def test_grad_clip_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped = optimizer.clip_by_global_norm(g, 0.5)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.3, 0.4], rtol=1e-6)
    small = {"a": jnp.asarray([0.1, 0.0])}
    np.testing.assert_allclose(
        np.asarray(optimizer.clip_by_global_norm(small, 0.5)["a"]), [0.1, 0.0], rtol=1e-6
    )


def test_teacher_step_flat_signature():
    cfg = CFG_TOK
    n = len(model.param_specs(cfg))
    step = steps.make_teacher_step(cfg)
    p = model.params_to_list(cfg, _params(cfg))
    zeros = [jnp.zeros_like(t) for t in p]
    x = _tok_batch(cfg, 4)
    y = jnp.zeros((4,), jnp.int32)
    out = step(*p, *zeros, *zeros, jnp.asarray(0.0), x, y, jnp.asarray(1e-3))
    assert len(out) == 3 * n + 3
    assert np.isfinite(float(out[-2]))  # loss


def test_distill_step_flat_signature():
    cfg = CFG_TOK
    n = len(model.param_specs(cfg))
    step = steps.make_distill_step(cfg, "had", ste=True)
    p = model.params_to_list(cfg, _params(cfg, 0))
    tp = model.params_to_list(cfg, _params(cfg, 1))
    zeros = [jnp.zeros_like(t) for t in p]
    x = _tok_batch(cfg, 4)
    sig = jnp.ones((cfg.n_layers,), jnp.float32)
    out = step(
        *p, *zeros, *zeros, jnp.asarray(0.0), *tp, x, sig, sig,
        jnp.asarray(1.0), jnp.asarray(1.0), jnp.asarray(1.0),
        jnp.asarray(1e-4), jnp.asarray(5.0),
    )
    assert len(out) == 3 * n + 3
    kl_att, kl_out = float(out[-2]), float(out[-1])
    assert np.isfinite(kl_att) and np.isfinite(kl_out)


def test_topn_sparse_softmax_sparsity():
    from compile.model import _topn_sparse_softmax

    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32), jnp.float32)
    p = np.asarray(_topn_sparse_softmax(x, 7.0))
    nz = (p > 0).sum(axis=-1)
    np.testing.assert_array_equal(nz, 7)  # no ties in continuous inputs
    np.testing.assert_allclose(p.sum(axis=-1), 1.0, rtol=1e-5)


def test_topn_runtime_equals_static_reference():
    from compile.kernels import ref
    from compile.model import _topn_sparse_softmax

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16), jnp.float32)
    for n_top in (1, 4, 16):
        p = np.asarray(_topn_sparse_softmax(x, float(n_top)))
        mask = np.asarray(ref.topn_mask_ref(x, n_top))
        np.testing.assert_array_equal(p > 0, mask)
