"""Store results-file validation: scripts/validate_store.py against a
synthetic bench-shaped results file (the exact record shapes
benches/store.rs writes), its failure modes (missing kinds, identity
breaks, checksum failures, the hydrate-vs-reprefill gate), and — when a
bench run has left one — the real results/store.jsonl."""

import json
import os
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..", "..")
sys.path.insert(0, os.path.join(REPO, "scripts"))

from validate_store import validate  # noqa: E402

PROVENANCE = {"run": "20260808-000000", "git_sha": "abc1234", "schema": 2}


def checkpoint_record(**overrides):
    rec = {
        "kind": "checkpoint",
        "cold_us": 1800.0,
        "mmap_us": 90.0,
        "identity_ok": True,
        **PROVENANCE,
    }
    rec.update(overrides)
    return rec


def spill_record(**overrides):
    rec = {
        "kind": "spill",
        "n_ctx": 4096,
        "spilled_bytes": 2359296,
        "spill_us": 4200.0,
        "hydrate_us": 3100.0,
        "reprefill_us": 250000.0,
        "identity_ok": True,
        "checksum_failures": 0,
        **PROVENANCE,
    }
    rec.update(overrides)
    return rec


def restart_record(**overrides):
    rec = {
        "kind": "restart",
        "spill_pages_out": 32,
        "spill_pages_in": 32,
        "hydrate_hits": 1,
        "checksum_failures": 0,
        "identity_ok": True,
        **PROVENANCE,
    }
    rec.update(overrides)
    return rec


def full_results():
    return [checkpoint_record(), spill_record(), restart_record()]


def write(tmp_path, records):
    path = tmp_path / "store.jsonl"
    if isinstance(records, str):
        path.write_text(records)
    else:
        path.write_text("".join(json.dumps(r) + "\n" for r in records))
    return str(path)


def test_bench_shaped_results_pass(tmp_path):
    assert validate(write(tmp_path, full_results())) == []


def test_not_json_fails(tmp_path):
    problems = validate(write(tmp_path, "{not json\n"))
    assert any("not valid JSON" in p for p in problems)


def test_empty_file_fails(tmp_path):
    problems = validate(write(tmp_path, ""))
    assert problems and "empty" in problems[0]


def test_missing_file_fails(tmp_path):
    problems = validate(str(tmp_path / "nope.jsonl"))
    assert problems and "cannot read" in problems[0]


def test_missing_kind_fails(tmp_path):
    problems = validate(write(tmp_path, [checkpoint_record(), spill_record()]))
    assert any("missing record kinds" in p and "restart" in p for p in problems)


@pytest.mark.parametrize("mk", [checkpoint_record, spill_record, restart_record])
def test_identity_break_fails(tmp_path, mk):
    records = [r for r in full_results() if r["kind"] != mk()["kind"]] + [
        mk(identity_ok=False)
    ]
    problems = validate(write(tmp_path, records))
    assert any("identity_ok" in p for p in problems)


def test_checksum_failures_fail(tmp_path):
    records = [checkpoint_record(), spill_record(checksum_failures=2), restart_record()]
    problems = validate(write(tmp_path, records))
    assert any("failed verification" in p for p in problems)


def test_hydrate_gate_fires_at_long_context(tmp_path):
    slow = spill_record(hydrate_us=300000.0, reprefill_us=250000.0)
    problems = validate(write(tmp_path, [checkpoint_record(), slow, restart_record()]))
    assert any("must beat re-prefill" in p for p in problems)


def test_hydrate_gate_relaxed_at_short_context(tmp_path):
    # quick-mode runs use tiny contexts where disk latency can lose to a
    # cheap prefill; the gate only applies at >=4k
    short = spill_record(n_ctx=512, hydrate_us=300000.0, reprefill_us=250000.0)
    assert validate(write(tmp_path, [checkpoint_record(), short, restart_record()])) == []


def test_never_spilled_restart_fails(tmp_path):
    records = [checkpoint_record(), spill_record(), restart_record(spill_pages_out=0)]
    problems = validate(write(tmp_path, records))
    assert any("never spilled" in p for p in problems)


def test_missing_provenance_fails(tmp_path):
    rec = checkpoint_record()
    del rec["git_sha"]
    problems = validate(write(tmp_path, [rec, spill_record(), restart_record()]))
    assert any("provenance" in p and "git_sha" in p for p in problems)


def test_real_results_if_present():
    path = os.path.join(REPO, "results", "store.jsonl")
    if not os.path.exists(path):
        pytest.skip("no results/store.jsonl from a bench run")
    assert validate(path) == []
