"""Bit-ops Hamming path vs oracles: the identity sign(q).sign(k) = d - 2*ham.

Everything here must be BIT-exact (integer scores), not just allclose.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import bitops, ref
from compile.kernels.binarize import hard_sign


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def test_pack_bits_roundtrip_semantics():
    x = jnp.asarray([[1.0, -2.0, 0.0, -0.5] * 8])  # d=32
    packed = bitops.pack_bits(x)
    assert packed.shape == (1, 1)
    bits = np.asarray(packed)[0, 0]
    signs = np.asarray(hard_sign(x))[0]
    for i in range(32):
        assert ((bits >> i) & 1) == (1 if signs[i] > 0 else 0)


def test_popcount_small_values():
    xs = jnp.asarray([0, 1, 2, 3, 255, 2**31, 2**32 - 1], dtype=jnp.uint32)
    want = [0, 1, 1, 2, 8, 1, 32]
    np.testing.assert_array_equal(np.asarray(bitops.popcount_u32(xs)), want)


@settings(max_examples=30, deadline=None)
@given(v=st.integers(0, 2**32 - 1))
def test_popcount_hypothesis(v):
    got = int(bitops.popcount_u32(jnp.asarray([v], jnp.uint32))[0])
    assert got == bin(v).count("1")


@settings(max_examples=25, deadline=None)
@given(
    n_q=st.integers(1, 16),
    n_k=st.integers(1, 16),
    d=st.sampled_from([8, 16, 32, 64, 96, 128]),
    key=st.integers(0, 2**16),
)
def test_hamming_identity(n_q, n_k, d, key):
    """d - 2*ham == sign-dot, bit-exact, including non-multiple-of-32 d."""
    q = _rand(key, (n_q, d))
    k = _rand(key + 1, (n_k, d))
    want = np.asarray(ref.had_scores_ref(q, k)).astype(np.int32)
    got = np.asarray(bitops.binary_scores_from_float(q, k))
    np.testing.assert_array_equal(got, want)


def test_hamming_distance_range():
    q = _rand(0, (8, 32))
    k = _rand(1, (8, 32))
    ham = np.asarray(ref.hamming_distance_ref(q, k))
    assert ham.min() >= 0 and ham.max() <= 32


def test_hamming_self_distance_zero():
    q = _rand(2, (8, 32))
    ham = np.asarray(ref.hamming_distance_ref(q, q))
    np.testing.assert_array_equal(np.diag(ham), 0)


@settings(max_examples=10, deadline=None)
@given(
    bh=st.integers(1, 4),
    d=st.sampled_from([32, 64]),
    key=st.integers(0, 2**16),
)
def test_pallas_hamming_kernel(bh, d, key):
    n = 64
    q = _rand(key, (bh, n, d))
    k = _rand(key + 1, (bh, n, d))
    qp = bitops.pack_bits(q)
    kp = bitops.pack_bits(k)
    got = np.asarray(bitops.hamming_scores_pallas(qp, kp, d=d, block_q=32))
    want = np.asarray(ref.had_scores_ref(q, k)).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_packed_k_bytes():
    assert bitops.packed_k_bytes(1024, 64) == 1024 * 2 * 4
    # 32x smaller than f32 K
    assert bitops.packed_k_bytes(1024, 64) * 32 == 1024 * 64 * 4
