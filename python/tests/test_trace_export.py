"""Chrome-trace JSON validity: scripts/validate_trace.py against a
synthetic exporter-shaped trace (the Rust exporter's exact layout), its
failure modes, and — when a bench run under HAD_TRACE has left one —
the real results/trace/trace.json."""

import json
import os
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..", "..")
sys.path.insert(0, os.path.join(REPO, "scripts"))

from validate_trace import validate  # noqa: E402


def exporter_shaped_trace():
    """The shape rust/src/obs/export.rs writes, in miniature."""
    return {
        "displayTimeUnit": "ms",
        "traceEvents": [
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "had (scalar)"}},
            {"name": "trace_meta", "ph": "M", "pid": 1, "tid": 0,
             "args": {"dropped_spans": 0}},
            {"name": "request", "cat": "had", "ph": "X", "pid": 1, "tid": 1,
             "ts": 10, "dur": 900, "args": {"id": 1, "parent": 0, "payload": 96}},
            {"name": "queue_wait", "cat": "had", "ph": "X", "pid": 1, "tid": 1,
             "ts": 10, "dur": 40, "args": {"id": 2, "parent": 1, "payload": 0}},
            {"name": "attention", "cat": "had", "ph": "X", "pid": 1, "tid": 2,
             "ts": 60, "dur": 300, "args": {"id": 3, "parent": 1, "payload": 0}},
            {"name": "sample", "cat": "had", "ph": "X", "pid": 1, "tid": 2,
             "ts": 400, "dur": 5, "args": {"id": 4, "parent": 1, "payload": 0}},
        ],
    }


def write(tmp_path, trace):
    path = tmp_path / "trace.json"
    path.write_text(trace if isinstance(trace, str) else json.dumps(trace))
    return str(path)


def test_exporter_shaped_trace_is_valid(tmp_path):
    assert validate(write(tmp_path, exporter_shaped_trace())) == []


def test_not_json_fails(tmp_path):
    problems = validate(write(tmp_path, "{not json"))
    assert problems and "not valid JSON" in problems[0]


def test_missing_trace_events_fails(tmp_path):
    problems = validate(write(tmp_path, {"displayTimeUnit": "ms"}))
    assert problems and "traceEvents" in problems[0]


def test_span_missing_duration_fails(tmp_path):
    trace = exporter_shaped_trace()
    del trace["traceEvents"][2]["dur"]
    problems = validate(write(tmp_path, trace))
    assert any("dur" in p for p in problems)


def test_unresolved_parent_fails(tmp_path):
    trace = exporter_shaped_trace()
    trace["traceEvents"][3]["args"]["parent"] = 999
    problems = validate(write(tmp_path, trace))
    assert any("parent 999" in p for p in problems)


def test_unresolved_parent_tolerated_after_ring_drops(tmp_path):
    trace = exporter_shaped_trace()
    trace["traceEvents"][1]["args"]["dropped_spans"] = 3
    trace["traceEvents"][3]["args"]["parent"] = 999
    assert validate(write(tmp_path, trace)) == []


def test_empty_span_list_fails(tmp_path):
    trace = exporter_shaped_trace()
    trace["traceEvents"] = trace["traceEvents"][:2]  # metadata only
    problems = validate(write(tmp_path, trace))
    assert any("no span" in p for p in problems)


def test_real_trace_if_present():
    path = os.path.join(REPO, "results", "trace", "trace.json")
    if not os.path.exists(path):
        pytest.skip("no results/trace/trace.json (run a bench with HAD_TRACE first)")
    assert validate(path) == []
