"""Net results-file validation: scripts/validate_net.py against a
synthetic harness-shaped results file (the exact record shape
benches/net_stress.rs writes), its failure modes (missing scenarios,
un-retired streams, leaked pages, identity divergence, TTFT gate), and
— when a bench run has left one — the real results/net.jsonl."""

import json
import os
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..", "..")
sys.path.insert(0, os.path.join(REPO, "scripts"))

from validate_net import validate  # noqa: E402


def record(name, **overrides):
    """One harness-shaped scenario record (schema v2, provenance-stamped)."""
    rec = {
        "kind": "net",
        "name": name,
        "admitted": 8,
        "retired": 8,
        "done_events": 8,
        "leaked_bytes": 0,
        "watchdog_ok": True,
        "ttft_p99_us": 120000,
        "faults_injected": 0,
        "net_connections": 9,
        "net_requests": 9,
        "net_parse_errors": 0,
        "net_slow_writes": 0,
        "run": "20260808-000000",
        "git_sha": "abc1234",
        "schema": 2,
    }
    rec.update(overrides)
    return rec


def full_results():
    return [
        record("net_identity", identity_ok=True),
        record("net_burst"),
        record("net_slow_reader", net_slow_writes=12),
        record("net_disconnect_storm"),
        record("net_fault_sweep", faults_injected=5),
    ]


def write(tmp_path, records):
    path = tmp_path / "net.jsonl"
    if isinstance(records, str):
        path.write_text(records)
    else:
        path.write_text("".join(json.dumps(r) + "\n" for r in records))
    return str(path)


def test_harness_shaped_results_pass(tmp_path):
    assert validate(write(tmp_path, full_results())) == []


def test_not_json_fails(tmp_path):
    problems = validate(write(tmp_path, "{not json\n"))
    assert any("not valid JSON" in p for p in problems)


def test_empty_file_fails(tmp_path):
    problems = validate(write(tmp_path, ""))
    assert problems and "empty" in problems[0]


def test_missing_file_fails(tmp_path):
    problems = validate(str(tmp_path / "nope.jsonl"))
    assert problems and "cannot read" in problems[0]


def test_missing_scenario_fails(tmp_path):
    recs = [r for r in full_results() if r["name"] != "net_slow_reader"]
    problems = validate(write(tmp_path, recs))
    assert any("missing scenarios" in p and "net_slow_reader" in p for p in problems)


def test_unretired_stream_fails(tmp_path):
    recs = full_results()
    recs[1]["retired"] = recs[1]["admitted"] - 1
    problems = validate(write(tmp_path, recs))
    assert any("vanished without a StopReason" in p for p in problems)


def test_leaked_pages_fail(tmp_path):
    recs = full_results()
    recs[3]["leaked_bytes"] = 4096
    problems = validate(write(tmp_path, recs))
    assert any("still in the page pool" in p for p in problems)


def test_identity_divergence_fails(tmp_path):
    recs = full_results()
    recs[0]["identity_ok"] = False
    problems = validate(write(tmp_path, recs))
    assert any("diverged from the direct engine" in p for p in problems)


def test_sweep_without_faults_fails(tmp_path):
    recs = full_results()
    recs[4]["faults_injected"] = 0
    problems = validate(write(tmp_path, recs))
    assert any("never fired" in p for p in problems)


def test_slow_reader_without_slow_writes_fails(tmp_path):
    recs = full_results()
    recs[2]["net_slow_writes"] = 0
    problems = validate(write(tmp_path, recs))
    assert any("slow-write counter" in p for p in problems)


def test_ttft_gate_fails_and_is_tunable(tmp_path):
    recs = full_results()
    recs[1]["ttft_p99_us"] = 9_000_000
    path = write(tmp_path, recs)
    assert any("TTFT" in p for p in validate(path))
    assert validate(path, max_ttft_p99_us=10_000_000) == []


def test_missing_provenance_fails(tmp_path):
    recs = full_results()
    del recs[0]["git_sha"]
    problems = validate(write(tmp_path, recs))
    assert any("provenance" in p for p in problems)


def test_foreign_kinds_are_ignored(tmp_path):
    recs = full_results() + [{"kind": "stress", "name": "burst"}]
    assert validate(write(tmp_path, recs)) == []


def test_real_results_if_present():
    path = os.path.join(REPO, "results", "net.jsonl")
    if not os.path.exists(path):
        pytest.skip("no results/net.jsonl (run cargo bench --bench net_stress first)")
    assert validate(path) == []
