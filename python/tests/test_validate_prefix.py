"""Prefix-sharing results-file validation: scripts/validate_prefix.py
against a synthetic bench-shaped results file (the exact record shapes
benches/prefix.rs writes), its failure modes (missing stream counts,
identity breaks, re-prefilled shared stripes, residency regressions,
pool leaks), and — when a bench run has left one — the real
results/prefix.jsonl."""

import json
import os
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..", "..")
sys.path.insert(0, os.path.join(REPO, "scripts"))

from validate_prefix import validate  # noqa: E402

PROVENANCE = {"run": "20260808-000000", "git_sha": "abc1234", "schema": 2}


def streams_record(n, **overrides):
    share_tokens = 4032  # floor(4095 / 64) * 64 for the 4096-token prompt
    rec = {
        "kind": "streams",
        "streams": n,
        "prompt_tokens": 4096,
        "share_tokens": share_tokens,
        "baseline_ms": 120.0 * n,
        "sharing_ms": 120.0 + 2.0 * n,
        "shared_pages": 504,
        "prefix_hits": max(0, n - 1),
        "tokens_reused": (n - 1) * share_tokens,
        "expected_reuse": (n - 1) * share_tokens,
        "cow_copies": 0,
        "baseline_bytes": 1048576 * n,
        "sharing_bytes": 1048576 + 4096 * n,
        "bytes_ratio": (1048576 + 4096 * n) / (1048576.0 * n),
        "identity_ok": True,
        "prefill_once": True,
        "drained_ok": True,
        **PROVENANCE,
    }
    rec.update(overrides)
    return rec


def full_results():
    return [streams_record(n) for n in (1, 4, 16)]


def write(tmp_path, records):
    path = tmp_path / "prefix.jsonl"
    if isinstance(records, str):
        path.write_text(records)
    else:
        path.write_text("".join(json.dumps(r) + "\n" for r in records))
    return str(path)


def test_bench_shaped_results_pass(tmp_path):
    assert validate(write(tmp_path, full_results())) == []


def test_not_json_fails(tmp_path):
    problems = validate(write(tmp_path, "{not json\n"))
    assert any("not valid JSON" in p for p in problems)


def test_empty_file_fails(tmp_path):
    problems = validate(write(tmp_path, ""))
    assert problems and "empty" in problems[0]


def test_missing_file_fails(tmp_path):
    problems = validate(str(tmp_path / "nope.jsonl"))
    assert problems and "cannot read" in problems[0]


def test_missing_stream_count_fails(tmp_path):
    problems = validate(write(tmp_path, [streams_record(1), streams_record(4)]))
    assert any("missing stream counts" in p and "16" in p for p in problems)


def test_identity_break_fails(tmp_path):
    records = [streams_record(1), streams_record(4), streams_record(16, identity_ok=False)]
    problems = validate(write(tmp_path, records))
    assert any("identity_ok" in p for p in problems)


def test_reprefilled_stripe_fails(tmp_path):
    # a follower re-executed a shared stripe: reused falls short of the
    # exact (n-1) * share_tokens target and the prefill_once flag drops
    broken = streams_record(16, tokens_reused=10 * 4032, prefill_once=False)
    problems = validate(write(tmp_path, [streams_record(1), streams_record(4), broken]))
    assert any("prefill_once" in p for p in problems)
    assert any("expected exactly" in p for p in problems)


def test_no_shareable_stripe_fails(tmp_path):
    # a degenerate sweep (prompt shorter than one page) exercises nothing
    hollow = streams_record(
        16, share_tokens=0, tokens_reused=0, expected_reuse=0
    )
    problems = validate(write(tmp_path, [streams_record(1), streams_record(4), hollow]))
    assert any("expected_reuse is zero" in p for p in problems)


def test_residency_regression_fails(tmp_path):
    fat = streams_record(16, bytes_ratio=1.0)
    problems = validate(write(tmp_path, [streams_record(1), streams_record(4), fat]))
    assert any("not deduplicated" in p for p in problems)


def test_single_stream_residency_exempt(tmp_path):
    # one stream has nothing to share: equal residency is correct there
    lone = streams_record(1, bytes_ratio=1.0)
    assert validate(write(tmp_path, [lone, streams_record(4), streams_record(16)])) == []


def test_pool_leak_fails(tmp_path):
    leaky = streams_record(16, drained_ok=False)
    problems = validate(write(tmp_path, [streams_record(1), streams_record(4), leaky]))
    assert any("drained_ok" in p for p in problems)


def test_missing_provenance_fails(tmp_path):
    rec = streams_record(1)
    del rec["git_sha"]
    problems = validate(write(tmp_path, [rec, streams_record(4), streams_record(16)]))
    assert any("provenance" in p and "git_sha" in p for p in problems)


def test_real_results_if_present():
    path = os.path.join(REPO, "results", "prefix.jsonl")
    if not os.path.exists(path):
        pytest.skip("no results/prefix.jsonl from a bench run")
    assert validate(path) == []
