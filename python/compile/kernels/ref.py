"""Pure-jnp oracles for every kernel in this package.

These are the correctness ground truth: deliberately simple, no Pallas, no
bit tricks, no fused ops. The pytest suite (python/tests/) asserts that the
Pallas kernels and the bit-ops formulations match these to float tolerance
(and bit-exactly where integers are involved).

Conventions shared with the kernels:
  * sign(0) = +1 (see binarize.hard_sign)
  * top-N selection per query row, ties broken by lowest key index
    (the lax.top_k convention)
  * softmax is computed over ONLY the selected N logits, after scaling by
    1/sqrt(d_head) (paper Eq. 7)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .binarize import hard_sign

__all__ = [
    "standard_attention_ref",
    "had_scores_ref",
    "topn_mask_ref",
    "had_attention_ref",
    "hamming_distance_ref",
]


def standard_attention_ref(q, k, v, *, scale=None):
    """Vanilla softmax(QK^T/sqrt(d)) V  (paper Eqs. 1-3).

    q: (..., n_q, d), k: (..., n_k, d), v: (..., n_k, d_v).
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", probs, v)


def had_scores_ref(q, k):
    """Binarized attention logits  A_l = sign(Q) . sign(K)^T  (Eqs. 4-5).

    Output entries are integers in {-d, -d+2, ..., d} represented in the
    input dtype.
    """
    return jnp.einsum("...qd,...kd->...qk", hard_sign(q), hard_sign(k))


def hamming_distance_ref(q, k):
    """Hamming distance between sign patterns, element-count convention.

    ham(q, k) = #{i : sign(q_i) != sign(k_i)}.  Related to the binary dot
    product by  sign(q).sign(k) = d - 2*ham(q, k).
    """
    qs = hard_sign(q)
    ks = hard_sign(k)
    neq = (qs[..., :, None, :] != ks[..., None, :, :]).astype(jnp.int32)
    return jnp.sum(neq, axis=-1)


def topn_mask_ref(scores, n_top):
    """Boolean mask of the top-``n_top`` entries per row (Eq. 6).

    Ties are broken by preferring the lower column index, matching
    lax.top_k. Implemented with a stable argsort so it shares no code with
    the kernels it checks.
    """
    n = scores.shape[-1]
    n_top = min(n_top, n)
    # Stable argsort of descending score; equal scores keep index order.
    order = jnp.argsort(-scores, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    return ranks < n_top


def had_attention_ref(q, k, v, n_top, *, d_scale=None):
    """Full HAD attention oracle (paper Eqs. 4-8).

    1. binarize q, k with hard_sign
    2. integer logits A_l = Q K^T
    3. keep top-N logits per query
    4. softmax over the kept logits scaled by 1/sqrt(d_head)
    5. accumulate over V

    ``d_scale`` overrides the 1/sqrt(d_head) scaling (used by tests).
    """
    d = q.shape[-1]
    if d_scale is None:
        d_scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = had_scores_ref(q, k)
    mask = topn_mask_ref(logits, n_top)
    neg_inf = jnp.asarray(-1e30, logits.dtype)
    masked = jnp.where(mask, logits * d_scale, neg_inf)
    probs = jax.nn.softmax(masked, axis=-1)
    # Entries outside the mask got exp(-1e30 - max) == 0 exactly.
    probs = jnp.where(mask, probs, 0.0)
    return jnp.einsum("...qk,...kd->...qd", probs, v)
