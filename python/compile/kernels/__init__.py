"""L1 kernels: Pallas HAD attention, bit-ops Hamming path, binarizers.

`ref` holds the pure-jnp oracles every kernel is tested against.
"""

from . import binarize, bitops, had_attention, ref  # noqa: F401
