"""Bit-exact Hamming formulation of the binarized score matrix.

This module proves, in code, the identity the whole paper rests on:

    sign(q) . sign(k)  =  d  -  2 * ham(pack(q), pack(k))

where ``pack`` packs the sign bits of a d-vector into ceil(d/32) uint32
words and ``ham`` is XOR + popcount. The paper's CAM hardware evaluates the
right-hand side; the TPU kernel evaluates the left-hand side on the MXU;
the Rust CPU fast path (rust/src/binary/) evaluates the right-hand side
with u64 popcounts. The pytest suite checks all three agree bit-exactly
through this module's oracle-vs-kernel pairing.

Also includes a Pallas kernel variant (`hamming_scores_pallas`) operating
on pre-packed keys/queries, demonstrating that the packed layout (32x
smaller K) is expressible in the same kernel language as the MXU variant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .binarize import hard_sign

INTERPRET = True


def pack_bits(x) -> jax.Array:
    """Pack sign bits of the last axis into uint32 words.

    bit i of word w holds sign(x[..., 32*w + i]) >= 0. The last axis length
    must be a multiple of 32 (models in this repo use d_head in {16,32,64,
    128}; d<32 callers pad with +1 signs which contribute equally to both
    sides of the Hamming identity and cancel).
    """
    d = x.shape[-1]
    if d % 32 != 0:
        pad = 32 - d % 32
        # Pad with +1 signs: XOR of equal bits is 0, so distances are
        # unchanged relative to the padded dot product d' = d + pad.
        x = jnp.concatenate([x, jnp.ones(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
        d = x.shape[-1]
    bits = (x >= 0).astype(jnp.uint32)
    bits = bits.reshape(x.shape[:-1] + (d // 32, 32))
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint32)


def popcount_u32(x) -> jax.Array:
    """Branch-free 32-bit popcount (Hacker's Delight 5-2) in jnp."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def hamming_scores(q_packed, k_packed, d: int) -> jax.Array:
    """Binary dot products from packed patterns: d - 2*ham.

    q_packed: (..., n_q, w) uint32, k_packed: (..., n_k, w) uint32.
    ``d`` is the ORIGINAL (unpadded) dimension; padding bits are equal in
    both operands so they never contribute to the XOR.
    Returns int32 (..., n_q, n_k) equal to sign(q).sign(k).
    """
    x = q_packed[..., :, None, :] ^ k_packed[..., None, :, :]
    ham = jnp.sum(popcount_u32(x), axis=-1)
    return d - 2 * ham


def binary_scores_from_float(q, k) -> jax.Array:
    """End-to-end packed path: float q,k -> packed -> Hamming scores."""
    d = q.shape[-1]
    return hamming_scores(pack_bits(q), pack_bits(k), d)


def _hamming_kernel(q_ref, k_ref, o_ref, *, d: int):
    qp = q_ref[...]
    kp = k_ref[...]
    x = qp[:, None, :] ^ kp[None, :, :]
    ham = jnp.sum(popcount_u32(x), axis=-1)
    o_ref[...] = (d - 2 * ham).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("d", "block_q"))
def hamming_scores_pallas(q_packed, k_packed, *, d: int, block_q: int = 64):
    """Pallas kernel over packed operands: (bh, n_q, w) x (bh, n_k, w).

    The packed-K slab per (batch*head) is w = d/32 words wide — the 32x
    VMEM saving that lets long-context K stay resident (DESIGN.md
    §Hardware-Adaptation).
    """
    bh, n_q, w = q_packed.shape
    n_k = k_packed.shape[1]
    block_q = min(block_q, n_q)
    if n_q % block_q != 0:
        raise ValueError(f"n_q={n_q} not divisible by block_q={block_q}")
    kernel = functools.partial(_hamming_kernel, d=d)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, n_k, w), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, n_k), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n_q, n_k), jnp.int32),
        interpret=INTERPRET,
    )(q_packed, k_packed)


def packed_k_bytes(n_k: int, d: int) -> int:
    """Bytes of a packed key cache row-major (hwsim + DESIGN.md numbers)."""
    return n_k * ((d + 31) // 32) * 4
