"""L1 Pallas kernel: fused HAD attention (paper Eqs. 4-8, Figure 2).

One fused kernel computes, per (batch*head, query-block) grid cell:

    sign(Q) sign(K)^T  ->  top-N per query  ->  softmax(./sqrt(d))  ->  A V

TPU mapping (see DESIGN.md §Hardware-Adaptation): the binary score matrix
is realized as a ±1 matmul (bit-exact in f32/bf16 because |scores| <= d_head
<= 256), which runs on the MXU at full throughput; K and V stay resident in
VMEM across all query blocks (binarized K is 32x smaller once bit-packed at
rest, which is what makes long-context K residency possible — the packed
layout itself is exercised by kernels/bitops.py and the Rust fast path);
top-N uses lax.top_k (sorting network) and the AV accumulation gathers only
N rows of V per query.

The kernel MUST run with interpret=True in this environment: real TPU
lowering emits Mosaic custom-calls that the CPU PJRT plugin cannot execute.
`interpret` is therefore a module-level switch that aot.py leaves True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .binarize import hard_sign

# CPU PJRT can only execute interpret-mode Pallas. Keep this True.
INTERPRET = True

# Max d_head for which ±1 matmul accumulation is integer-exact in bf16.
MAX_EXACT_D_HEAD = 256


def _had_attention_kernel(q_ref, k_ref, v_ref, t_ref, o_ref, *, n_top: int, d_scale: float):
    """Kernel body. Shapes (per grid cell):

    q_ref: (block_q, d)   — one query block of one (batch, head)
    k_ref: (n_k, d)       — all keys of that (batch, head), VMEM resident
    v_ref: (n_k, d_v)     — all values
    t_ref: (1, 1)         — softmax temperature (sigma_q*sigma_k, runtime)
    o_ref: (block_q, d_v) — output block
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    temp = t_ref[0, 0]

    # Binarize and score: ±1 matmul == d - 2*hamming, exact in f32.
    qb = hard_sign(q)
    kb = hard_sign(k)
    scores = jnp.dot(qb, kb.T, preferred_element_type=jnp.float32)

    # Top-N per query row (Eq. 6), ties broken by lowest key index (the
    # lax.top_k convention shared with ref.topn_mask_ref). Implemented as
    # a stable variadic sort + slice rather than lax.top_k: jax lowers
    # top_k to a `topk(..., largest=true)` HLO op that the xla_extension
    # 0.5.1 text parser predates; variadic `sort` round-trips cleanly.
    n_k_total = scores.shape[-1]
    iota = lax.broadcasted_iota(jnp.int32, scores.shape, len(scores.shape) - 1)
    sorted_neg, sorted_idx = lax.sort(
        (-scores, iota), dimension=-1, is_stable=True, num_keys=1
    )
    top_vals = -sorted_neg[..., :n_top]
    top_idx = sorted_idx[..., :n_top]
    del n_k_total

    # Softmax over only the kept logits, scaled by temp/sqrt(d_head)
    # (Eq. 7; temp carries the sigma_q*sigma_k standardization factor).
    logits = top_vals * (d_scale * temp)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    expl = jnp.exp(logits)
    probs = expl / jnp.sum(expl, axis=-1, keepdims=True)

    # Sparse accumulation over V: gather N rows per query (Eq. 8).
    v_gathered = jnp.take(v, top_idx, axis=0)  # (block_q, n_top, d_v)
    o_ref[...] = jnp.einsum("qn,qnd->qd", probs, v_gathered).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_top", "block_q"))
def had_attention(q, k, v, *, n_top: int, block_q: int = 128, temp=None):
    """Fused HAD attention over (B, H, n, d) tensors.

    Args:
      q: (B, H, n_q, d) continuous queries (binarized inside the kernel).
      k: (B, H, n_k, d) continuous keys.
      v: (B, H, n_k, d_v) values (full precision, per the paper).
      n_top: sparsity parameter N — attention entries kept per query.
      block_q: query rows per grid cell (VMEM tile height).
      temp: optional runtime softmax temperature scalar — carries the
        sigma_q*sigma_k standardization product of the calibrated model
        (paper §3.4); defaults to 1.

    Returns (B, H, n_q, d_v).
    """
    b, h, n_q, d = q.shape
    n_k = k.shape[2]
    d_v = v.shape[3]
    if d > MAX_EXACT_D_HEAD:
        raise ValueError(f"d_head={d} breaks ±1-matmul integer exactness (max {MAX_EXACT_D_HEAD})")
    n_top = min(n_top, n_k)
    block_q = min(block_q, n_q)
    if n_q % block_q != 0:
        raise ValueError(f"n_q={n_q} must be divisible by block_q={block_q}")

    d_scale = 1.0 / (float(d) ** 0.5)
    if temp is None:
        temp = jnp.ones((), jnp.float32)
    temp = jnp.asarray(temp, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_had_attention_kernel, n_top=n_top, d_scale=d_scale)

    qf = q.reshape(b * h, n_q, d)
    kf = k.reshape(b * h, n_k, d)
    vf = v.reshape(b * h, n_k, d_v)

    grid = (b * h, n_q // block_q)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Query block: march down the query axis per grid step.
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            # K and V: whole (n_k, d) slab per (batch*head) — VMEM resident
            # across the inner query-block loop (packed-K residency story).
            pl.BlockSpec((None, n_k, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, n_k, d_v), lambda i, j: (i, 0, 0)),
            # Runtime softmax temperature (broadcast scalar).
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d_v), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, n_q, d_v), v.dtype),
        interpret=INTERPRET,
    )(qf, kf, vf, temp)
    return out.reshape(b, h, n_q, d_v)


def vmem_report(*, n_k: int, d: int, d_v: int, block_q: int, n_top: int) -> dict:
    """Static VMEM/MXU estimate for one grid cell (DESIGN.md §Perf, L1).

    Returns byte counts for the resident tensors and an MXU utilization
    proxy: fraction of the (8,128)x(128,128) systolic pipeline kept busy by
    the score matmul given the tile shapes. Used by EXPERIMENTS.md §Perf —
    interpret-mode wallclock is NOT a TPU proxy.
    """
    f32 = 4
    q_bytes = block_q * d * f32
    k_bytes = n_k * d * f32
    k_packed_bytes = n_k * ((d + 31) // 32) * 4  # bit-packed at rest
    v_bytes = n_k * d_v * f32
    out_bytes = block_q * d_v * f32
    gather_bytes = block_q * n_top * d_v * f32
    total = q_bytes + k_bytes + v_bytes + out_bytes + gather_bytes
    # MXU proxy: matmul (block_q x d) @ (d x n_k); MXU tiles are 128x128.
    mxu_m = min(block_q, 128) / 128.0
    mxu_k = min(d, 128) / 128.0
    return {
        "q_bytes": q_bytes,
        "k_bytes": k_bytes,
        "k_packed_bytes": k_packed_bytes,
        "v_bytes": v_bytes,
        "gather_bytes": gather_bytes,
        "out_bytes": out_bytes,
        "total_bytes": total,
        "fits_16MiB_vmem": total <= 16 * 1024 * 1024,
        "mxu_tile_utilization": mxu_m * mxu_k,
    }
