"""Binarization primitives for Hamming Attention Distillation (HAD).

These implement the paper's Eq. (4) and the stage-wise relaxations of
Sections 3.5-3.7:

  stage 1:  Q = c * sigma * tanh(Q_c / (c * sigma))        (Eq. 13)
  stage 2:  Q =     sigma * tanh(Q_c / (c * sigma))        (Eq. 15)
  stage 3+: Q =     sigma * STE(Q_c / sigma)               (Eq. 18)

`sign` here is the binarization convention used throughout the repo:
sign(x) = +1 for x >= 0 and -1 otherwise (zero maps to +1 so the output is
always a valid {-1,+1} pattern — required for the Hamming identity
q.k = d - 2*ham(q,k)).

All functions are pure jnp and differentiable (the STE via custom_vjp), so
they can be used both inside Pallas kernels (interpret mode) and in the L2
training graphs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "hard_sign",
    "ste_sign",
    "tanh_binarize",
    "ste_binarize",
    "binarize_stage",
]


def hard_sign(x: jax.Array) -> jax.Array:
    """{-1,+1} sign with sign(0) = +1 (no zero outputs)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


@jax.custom_vjp
def ste_sign(x: jax.Array) -> jax.Array:
    """Straight-through estimator sign (paper Eqs. 16-17).

    Forward: hard_sign(x). Backward: identity gradient clipped to |x| <= 1.
    """
    return hard_sign(x)


def _ste_sign_fwd(x):
    return hard_sign(x), x


def _ste_sign_bwd(x, g):
    # dSTE/dx = 1 on [-1, 1], 0 elsewhere (Eq. 17).
    mask = (jnp.abs(x) <= 1.0).astype(g.dtype)
    return (g * mask,)


ste_sign.defvjp(_ste_sign_fwd, _ste_sign_bwd)


def tanh_binarize(x: jax.Array, sigma: jax.Array, c: jax.Array, outer_mult: jax.Array) -> jax.Array:
    """Stage 1/2 scaled-tanh relaxation of binarization.

    ``outer_mult`` selects the stage: pass ``c`` for stage 1 (Eq. 13) and
    ``1.0`` for stage 2 (Eq. 15). Keeping it a runtime scalar lets a single
    lowered HLO artifact serve both stages.
    """
    sigma = jnp.asarray(sigma, x.dtype)
    c = jnp.asarray(c, x.dtype)
    inner = c * sigma
    return outer_mult * sigma * jnp.tanh(x / inner)


def ste_binarize(x: jax.Array, sigma: jax.Array) -> jax.Array:
    """Stage 3/4 binarization: sigma * STE(x / sigma) (Eq. 18)."""
    sigma = jnp.asarray(sigma, x.dtype)
    return sigma * ste_sign(x / sigma)


def binarize_stage(x: jax.Array, sigma: jax.Array, c: jax.Array, outer_mult: jax.Array, *, ste: bool) -> jax.Array:
    """Dispatch helper used by the L2 model: tanh relaxation or STE."""
    if ste:
        return ste_binarize(x, sigma)
    return tanh_binarize(x, sigma, c, outer_mult)
