"""Adam with global-norm gradient clipping (paper §3.9).

State layout contract with Rust: per parameter tensor, first moment `m`
then second moment `v`, in param_specs order, plus a single scalar step
counter `t`. The Rust side allocates/checkpoints this state; the lowered
train-step artifacts update it functionally.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]

# Paper §3.9: Adam, grad clip at magnitude 0.5.
BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8
CLIP_NORM = 0.5


def clip_by_global_norm(grads: Params, max_norm: float = CLIP_NORM) -> Params:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def adam_update(
    params: Params,
    grads: Params,
    m: Params,
    v: Params,
    t: jax.Array,
    lr: jax.Array,
) -> Tuple[Params, Params, Params, jax.Array]:
    """One clipped Adam step; returns (params', m', v', t')."""
    grads = clip_by_global_norm(grads)
    t_new = t + 1.0
    bc1 = 1.0 - BETA1**t_new
    bc2 = 1.0 - BETA2**t_new

    def upd(p, g, m_, v_):
        m_n = BETA1 * m_ + (1.0 - BETA1) * g
        v_n = BETA2 * v_ + (1.0 - BETA2) * (g * g)
        step = lr * (m_n / bc1) / (jnp.sqrt(v_n / bc2) + EPS)
        return p - step, m_n, v_n

    new_p: Params = {}
    new_m: Params = {}
    new_v: Params = {}
    for k in params:
        new_p[k], new_m[k], new_v[k] = upd(params[k], grads[k], m[k], v[k])
    return new_p, new_m, new_v, t_new
