"""Exported step functions: the exact graphs that become HLO artifacts.

Every function here takes/returns FLAT tuples of arrays so the PJRT-side
calling convention in Rust is positional and dtype-stable:

  teacher_step : params*, m*, v*, t, x, y, lr
              -> params'*, m'*, v'*, t', loss, acc
  distill_step : s_params*, m*, v*, t, t_params*, x,
                 sigma_q, sigma_k, c, outer_mult, att_w, lr, n_top
              -> s_params'*, m'*, v'*, t', loss_att, loss_out
  fwd          : params*, x, sigma_q, sigma_k, n_top -> logits
  calib        : params*, x -> sigma_q, sigma_k

`*` expands in param_specs(cfg) order (model.py — the layout contract).
Scalars travel as f32[] literals so ONE artifact serves every training
stage: stage 1/2 differ only in (c, outer_mult); stage 4 sets att_w = 0; the
sparsity parameter N (n_top, f32 floor'd) is runtime so the Figure-3 N
sweep and Figure-5 linear-N scaling reuse one artifact per graph.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import model, optimizer
from .model import ModelConfig, Params


def cross_entropy(logits, y):
    lp = jax.nn.log_softmax(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: the old HLO text
    # converter in xla_extension 0.5.1 rejects batched gathers.
    onehot = jax.nn.one_hot(y, logits.shape[-1], dtype=lp.dtype)
    return -jnp.mean(jnp.sum(lp * onehot, axis=-1))


def accuracy(logits, y):
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


def _split3(flat, n):
    return flat[:n], flat[n : 2 * n], flat[2 * n : 3 * n]


def make_teacher_step(cfg: ModelConfig):
    """Cross-entropy pre-training step for the teacher (standard attn)."""
    n = len(model.param_specs(cfg))

    def step(*args):
        flat = list(args)
        p_list, m_list, v_list = _split3(flat, n)
        t, x, y, lr = flat[3 * n : 3 * n + 4]
        params = model.params_from_list(cfg, p_list)
        m = model.params_from_list(cfg, m_list)
        v = model.params_from_list(cfg, v_list)

        def loss_fn(params):
            logits = model.forward(params, x, cfg, "standard")
            return cross_entropy(logits, y), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, m, v, t = optimizer.adam_update(params, grads, m, v, t, lr)
        acc = accuracy(logits, y)
        return tuple(
            model.params_to_list(cfg, params)
            + model.params_to_list(cfg, m)
            + model.params_to_list(cfg, v)
            + [t, loss, acc]
        )

    return step


def make_distill_step(cfg: ModelConfig, variant: str, ste: bool):
    """One distillation step (paper Algorithm 1, stages 1-4).

    variant in {"had", "bit", "sab"}; ste=False gives the tanh-relaxation
    graph (stages 1-2), ste=True the STE graph (stages 3-4).
    """
    n = len(model.param_specs(cfg))

    def step(*args):
        flat = list(args)
        s_list, m_list, v_list = _split3(flat, n)
        rest = flat[3 * n :]
        t = rest[0]
        t_list = rest[1 : 1 + n]
        x, sigma_q, sigma_k, c, outer_mult, att_w, lr, n_top = rest[1 + n : 9 + n]
        s_params = model.params_from_list(cfg, s_list)
        t_params = model.params_from_list(cfg, t_list)
        m = model.params_from_list(cfg, m_list)
        v = model.params_from_list(cfg, v_list)

        def loss_fn(s_params):
            z_s, z_t, kl_att = model.distill_forward(
                s_params, t_params, x, cfg, variant,
                ste=ste, c=c, outer_mult=outer_mult,
                sigma_q=sigma_q, sigma_k=sigma_k, n_top=n_top,
            )
            kl_out = model.kl_output(z_t, z_s)
            # L = att_w * L_KL-att + L_KL-out  (Eq. 11; att_w=0 in stage 4)
            return att_w * kl_att + kl_out, (kl_att, kl_out)

        (_, (kl_att, kl_out)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            s_params
        )
        s_params, m, v, t = optimizer.adam_update(s_params, grads, m, v, t, lr)
        return tuple(
            model.params_to_list(cfg, s_params)
            + model.params_to_list(cfg, m)
            + model.params_to_list(cfg, v)
            + [t, kl_att, kl_out]
        )

    return step


def make_fwd(cfg: ModelConfig, variant: str, use_pallas: bool = False):
    """Inference forward: params*, x, sigma_q, sigma_k, n_top -> logits."""
    n = len(model.param_specs(cfg))

    def fwd(*args):
        p_list = list(args[:n])
        x, sigma_q, sigma_k, n_top = args[n : n + 4]
        params = model.params_from_list(cfg, p_list)
        logits = model.forward(
            params, x, cfg, variant,
            ste=True, c=0.05, outer_mult=1.0,
            sigma_q=sigma_q, sigma_k=sigma_k, n_top=n_top,
            use_pallas=use_pallas,
        )
        return (logits,)

    return fwd


def make_calib(cfg: ModelConfig):
    """Standardization pass: params*, x -> per-layer (sigma_q, sigma_k)."""
    n = len(model.param_specs(cfg))

    def calib(*args):
        p_list = list(args[:n])
        x = args[n]
        params = model.params_from_list(cfg, p_list)
        sq, sk = model.qk_std(params, x, cfg)
        return (sq, sk)

    return calib


def example_inputs(cfg: ModelConfig, kind: str, batch: int):
    """ShapeDtypeStructs for lowering each artifact kind."""
    f32 = jnp.float32
    i32 = jnp.int32
    S = jax.ShapeDtypeStruct
    n = len(model.param_specs(cfg))
    p_specs = [S(shape, f32) for _, shape, _ in model.param_specs(cfg)]
    if cfg.vocab > 0:
        x = S((batch, cfg.n_ctx), i32)
    else:
        x = S((batch, cfg.n_patches, cfg.input_dim), f32)
    y = S((batch,), i32)
    scalar = S((), f32)
    sig = S((cfg.n_layers,), f32)

    if kind == "teacher_step":
        return p_specs * 3 + [scalar, x, y, scalar]
    if kind == "distill_step":
        return (
            p_specs * 3
            + [scalar]
            + p_specs
            + [x, sig, sig, scalar, scalar, scalar, scalar, scalar]
        )
    if kind == "fwd":
        return p_specs + [x, sig, sig, scalar]
    if kind == "calib":
        return p_specs + [x]
    raise ValueError(f"unknown artifact kind {kind!r}")
