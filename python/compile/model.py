"""L2: transformer encoder with swappable attention variants (HAD + baselines).

This is the build-time model definition. It is lowered ONCE per
(config, variant, kind) by aot.py into HLO text artifacts that the Rust
coordinator executes via PJRT — Python never runs on the request path.

Variants (paper §4 columns):
  standard  — softmax(QK^T/sqrt(d)) V; the teacher and the FP baseline.
  had       — sign-binarized Q/K + top-N sparse attention (the paper).
              Training graphs use the differentiable tanh/STE relaxations
              (kernels.binarize); eval graphs use the fused Pallas kernel.
  bit       — BiT-like full activation binarization baseline: Q, K, V all
              binarized with XNOR-net style mean-|x| scales, dense softmax.
  sab       — the "w/ SAB" ablation: HAD pipeline + BiViT-style
              softmax-aware binarization of the attention matrix.
  noattn    — attention block replaced by its V path only (O(n) ablation
              used for the Figure-1 runtime study).

Model shape: pre-LN encoder; CLS-token classification head. Two input
modes: token ids (vocab > 0) and dense patch vectors (vocab == 0, ViT-ish).
Layers are scanned with stacked parameters, which keeps the lowered HLO
size independent of depth and fixes the parameter layout contract with
Rust (see param_specs / DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels import binarize
from .kernels.had_attention import had_attention

Params = Dict[str, jax.Array]

VARIANTS = ("standard", "had", "bit", "sab", "noattn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters. `vocab == 0` selects dense-input mode."""

    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_ctx: int            # total sequence length INCLUDING the CLS position
    n_classes: int
    vocab: int = 0        # 0 => dense patch inputs
    input_dim: int = 0    # patch feature size when vocab == 0
    n_top: int = 30       # paper's N (top-N attention entries per query)
    block_q: int = 64     # Pallas query tile

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_patches(self) -> int:
        return self.n_ctx - 1

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ModelConfig":
        return ModelConfig(**d)


# ---------------------------------------------------------------------------
# Parameter layout contract (shared with Rust via the manifest)
# ---------------------------------------------------------------------------

# init kinds understood by the Rust initializer: "normal" (std 0.02),
# "zeros", "ones".
def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], str]]:
    """Ordered (name, shape, init) list — THE parameter contract.

    Rust materializes parameters, Adam moments, and checkpoints in exactly
    this order. Layer tensors are stacked on a leading n_layers axis.
    """
    L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    specs: List[Tuple[str, Tuple[int, ...], str]] = []
    if cfg.vocab > 0:
        specs.append(("tok_emb", (cfg.vocab, D), "normal"))
    else:
        specs.append(("patch_w", (cfg.input_dim, D), "normal"))
        specs.append(("patch_b", (D,), "zeros"))
        specs.append(("cls_tok", (D,), "normal"))
    specs.append(("pos_emb", (cfg.n_ctx, D), "normal"))
    layer = [
        ("ln1_g", (L, D), "ones"),
        ("ln1_b", (L, D), "zeros"),
        ("wq", (L, D, D), "normal"),
        ("bq", (L, D), "zeros"),
        ("wk", (L, D, D), "normal"),
        ("bk", (L, D), "zeros"),
        ("wv", (L, D, D), "normal"),
        ("bv", (L, D), "zeros"),
        ("wo", (L, D, D), "normal"),
        ("bo", (L, D), "zeros"),
        ("ln2_g", (L, D), "ones"),
        ("ln2_b", (L, D), "zeros"),
        ("w1", (L, D, F), "normal"),
        ("b1", (L, F), "zeros"),
        ("w2", (L, F, D), "normal"),
        ("b2", (L, D), "zeros"),
    ]
    specs.extend(layer)
    specs.extend(
        [
            ("lnf_g", (D,), "ones"),
            ("lnf_b", (D,), "zeros"),
            ("head_w", (D, cfg.n_classes), "normal"),
            ("head_b", (cfg.n_classes,), "zeros"),
        ]
    )
    return specs


def params_from_list(cfg: ModelConfig, tensors: List[jax.Array]) -> Params:
    specs = param_specs(cfg)
    assert len(tensors) == len(specs), (len(tensors), len(specs))
    return {name: t for (name, _, _), t in zip(specs, tensors)}


def params_to_list(cfg: ModelConfig, params: Params) -> List[jax.Array]:
    return [params[name] for name, _, _ in param_specs(cfg)]


LAYER_PARAM_NAMES = (
    "ln1_g ln1_b wq bq wk bk wv bv wo bo ln2_g ln2_b w1 b1 w2 b2".split()
)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Reference initializer (python tests only; Rust owns init at runtime)."""
    params: Params = {}
    for name, shape, kind in param_specs(cfg):
        if kind == "normal":
            key, sub = jax.random.split(key)
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        elif kind == "zeros":
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = jnp.ones(shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def embed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Token-id or dense-patch embedding; returns (B, n_ctx, D)."""
    if cfg.vocab > 0:
        h = params["tok_emb"][x]  # (B, n, D)
    else:
        h = x @ params["patch_w"] + params["patch_b"]  # (B, n_patches, D)
        cls = jnp.broadcast_to(params["cls_tok"], (h.shape[0], 1, cfg.d_model))
        h = jnp.concatenate([cls, h], axis=1)
    return h + params["pos_emb"][None, :, :]


def _split_heads(x, cfg: ModelConfig):
    b, n, _ = x.shape
    return x.reshape(b, n, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def _merge_heads(x, cfg: ModelConfig):
    b, h, n, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * d)


@jax.custom_vjp
def _topk_threshold(logits, n_top):
    """Value of the N-th largest logit per row; gradient-free by definition.

    custom_vjp keeps jnp.sort's JVP rule — which emits a batched gather the
    xla_extension 0.5.1 HLO text converter rejects (predates
    operand_batching_dims) — entirely out of differentiated graphs. The
    selection is discrete, so a zero cotangent is also the mathematically
    right answer.
    """
    n = logits.shape[-1]
    k = jnp.clip(n_top.astype(jnp.int32), 1, n)
    sorted_desc = -jnp.sort(-logits, axis=-1)
    # k-th largest via one-hot contraction instead of a batched gather.
    sel = jax.nn.one_hot(k - 1, n, dtype=logits.dtype)
    return jnp.sum(sorted_desc * sel, axis=-1, keepdims=True)


def _topk_threshold_fwd(logits, n_top):
    return _topk_threshold(logits, n_top), (logits, n_top)


def _topk_threshold_bwd(res, g):
    logits, n_top = res
    del g
    return jnp.zeros_like(logits), jnp.zeros_like(n_top)


_topk_threshold.defvjp(_topk_threshold_fwd, _topk_threshold_bwd)


def _topn_sparse_softmax(logits, n_top):
    """softmax over only the top-N logits per row (Eqs. 6-7).

    ``n_top`` is a RUNTIME scalar (f32, floor'd) so a single lowered
    artifact serves every N — the Figure-3 N-sweep and the Figure-5
    linear-N-scaling experiments reuse one graph. Implemented with a full
    descending sort + dynamic threshold instead of lax.top_k (which needs a
    static k).

    Threshold semantics: keep entries >= the N-th largest value. With tied
    logits at the boundary this keeps MORE than N entries (renormalized) —
    the fused Pallas kernel breaks ties by key index and keeps exactly N;
    the pytest suite pins down both behaviours. Training graphs only.
    """
    thresh = _topk_threshold(logits, jnp.asarray(n_top, jnp.float32))
    mask = logits >= thresh
    neg_inf = jnp.asarray(-1e30, logits.dtype)
    probs = jax.nn.softmax(jnp.where(mask, logits, neg_inf), axis=-1)
    return jnp.where(mask, probs, 0.0)


@jax.custom_vjp
def _ste_gate(hard, soft):
    """Forward `hard`, backward as if it were `soft` (identity STE)."""
    del soft
    return hard


def _ste_gate_fwd(hard, soft):
    return hard, None


def _ste_gate_bwd(_, g):
    return (jnp.zeros_like(g), g)


_ste_gate.defvjp(_ste_gate_fwd, _ste_gate_bwd)


def _sab_binarize(probs):
    """BiViT-style softmax-aware binarization of the attention matrix.

    Softmax outputs are non-negative with a long tail; binarize each row
    against its mean and rescale with the least-squares optimal scalar
    s = sum(p*b)/sum(b). STE carries gradients through the thresholding.
    """
    thresh = jnp.mean(probs, axis=-1, keepdims=True)
    b = (probs >= thresh).astype(probs.dtype)
    s = jnp.sum(probs * b, axis=-1, keepdims=True) / jnp.maximum(
        jnp.sum(b, axis=-1, keepdims=True), 1.0
    )
    hard = b * s
    return _ste_gate(hard, probs)


def _mean_abs_binarize(x):
    """XNOR-net style binarization used by the `bit` baseline: sign * mean|x|."""
    alpha = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
    return alpha * binarize.ste_sign(x)


def attention(
    x: jax.Array,
    lp: Params,
    cfg: ModelConfig,
    variant: str,
    *,
    ste: bool,
    c,
    outer_mult,
    sigma_q,
    sigma_k,
    n_top=None,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """One multi-head attention block under a given variant.

    Returns (output (B,n,D), att_logits (B,H,n,n) scaled by 1/sqrt(d) for
    the distillation loss, or None for `noattn`). The logits returned are
    PRE-sparsification, which is what Eq. 9 distills.
    """
    q = _split_heads(x @ lp["wq"] + lp["bq"], cfg)
    k = _split_heads(x @ lp["wk"] + lp["bk"], cfg)
    v = _split_heads(x @ lp["wv"] + lp["bv"], cfg)
    scale = 1.0 / (cfg.d_head**0.5)
    if n_top is None:
        n_top = cfg.n_top

    if variant == "noattn":
        out = _merge_heads(v, cfg)
        return out @ lp["wo"] + lp["bo"], None

    if variant == "standard":
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
        probs = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        out = _merge_heads(ctx, cfg)
        return out @ lp["wo"] + lp["bo"], logits

    if variant == "fp_topn":
        # Full-precision Q/K with top-N sparsification only — the Figure-3
        # progressive-N distillation subject.
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
        probs = _topn_sparse_softmax(logits, n_top)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        out = _merge_heads(ctx, cfg)
        return out @ lp["wo"] + lp["bo"], logits

    if variant == "bit":
        qb = _mean_abs_binarize(q)
        kb = _mean_abs_binarize(k)
        vb = _mean_abs_binarize(v)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * scale
        probs = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, vb)
        out = _merge_heads(ctx, cfg)
        return out @ lp["wo"] + lp["bo"], logits

    # had / sab: sigma-standardized binarization of Q and K (paper §3.4-3.7)
    qb = binarize.binarize_stage(q, sigma_q, c, outer_mult, ste=ste)
    kb = binarize.binarize_stage(k, sigma_k, c, outer_mult, ste=ste)

    if use_pallas and variant == "had" and ste:
        # Inference path: the fused L1 kernel. sign() inside the kernel
        # recovers the same ±1 pattern; sigma_q*sigma_k moves into the
        # softmax temperature. n_top is static here (production kernel).
        temp = (sigma_q * sigma_k).reshape(())
        ctx = had_attention(
            q, k, v, n_top=cfg.n_top, block_q=min(cfg.block_q, cfg.n_ctx), temp=temp
        )
        logits = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * scale
        out = _merge_heads(ctx, cfg)
        return out @ lp["wo"] + lp["bo"], logits

    logits = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * scale  # Eq. 5 (+ scale)

    if variant == "sab":
        probs = jax.nn.softmax(logits, axis=-1)
        probs = _sab_binarize(probs)
    else:
        probs = _topn_sparse_softmax(logits, n_top)

    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = _merge_heads(ctx, cfg)
    return out @ lp["wo"] + lp["bo"], logits


def _mlp(x, lp):
    h = x @ lp["w1"] + lp["b1"]
    h = jax.nn.gelu(h)
    return h @ lp["w2"] + lp["b2"]


def _layer(h, lp, cfg, variant, *, ste, c, outer_mult, sq, sk, n_top, use_pallas):
    attn_in = layer_norm(h, lp["ln1_g"], lp["ln1_b"])
    attn_out, att_logits = attention(
        attn_in, lp, cfg, variant,
        ste=ste, c=c, outer_mult=outer_mult, sigma_q=sq, sigma_k=sk,
        n_top=n_top, use_pallas=use_pallas,
    )
    h = h + attn_out
    h = h + _mlp(layer_norm(h, lp["ln2_g"], lp["ln2_b"]), lp)
    return h, att_logits


def _stacked_layers(params: Params):
    return {name: params[name] for name in LAYER_PARAM_NAMES}


def forward(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    variant: str = "standard",
    *,
    ste: bool = True,
    c=1.0,
    outer_mult=1.0,
    sigma_q=None,
    sigma_k=None,
    n_top=None,
    use_pallas: bool = False,
    return_att: bool = False,
):
    """Full encoder forward. sigma_{q,k}: (n_layers,) runtime arrays.

    Returns logits (B, n_classes); with return_att also the stacked
    per-layer attention logits (L, B, H, n, n) — training-size models only.
    """
    L = cfg.n_layers
    if sigma_q is None:
        sigma_q = jnp.ones((L,), jnp.float32)
    if sigma_k is None:
        sigma_k = jnp.ones((L,), jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    outer_mult = jnp.asarray(outer_mult, jnp.float32)

    h = embed(params, x, cfg)

    def body(carry, xs):
        lp, sq, sk = xs
        h = carry
        h, att = _layer(
            h, lp, cfg, variant,
            ste=ste, c=c, outer_mult=outer_mult, sq=sq, sk=sk,
            n_top=n_top, use_pallas=use_pallas,
        )
        return h, (att if return_att else 0.0)

    h, atts = jax.lax.scan(body, h, (_stacked_layers(params), sigma_q, sigma_k))
    h = layer_norm(h, params["lnf_g"], params["lnf_b"])
    logits = h[:, 0, :] @ params["head_w"] + params["head_b"]
    if return_att:
        return logits, atts
    return logits


def qk_std(params: Params, x: jax.Array, cfg: ModelConfig):
    """Per-layer std of the continuous Q_c and K_c activations (paper §3.4).

    Returns (sigma_q (L,), sigma_k (L,)) for one minibatch; the Rust
    calibration loop averages this over 100 minibatches (Eq. 12).
    """
    h = embed(params, x, cfg)

    def body(carry, lp):
        h = carry
        attn_in = layer_norm(h, lp["ln1_g"], lp["ln1_b"])
        q = attn_in @ lp["wq"] + lp["bq"]
        k = attn_in @ lp["wk"] + lp["bk"]
        sq = jnp.std(q)
        sk = jnp.std(k)
        h, _ = _layer(
            h, lp, cfg, "standard",
            ste=True, c=1.0, outer_mult=1.0, sq=1.0, sk=1.0,
            n_top=None, use_pallas=False,
        )
        return h, (sq, sk)

    _, (sqs, sks) = jax.lax.scan(body, h, _stacked_layers(params))
    return sqs, sks


# ---------------------------------------------------------------------------
# Joint teacher/student forward for distillation (memory-lean: the KL-att
# accumulates inside the layer scan instead of stacking (L,B,H,n,n) logits)
# ---------------------------------------------------------------------------


def kl_attention_rows(t_logits, s_logits):
    """Eq. 9 with softmax-normalized teacher weights (numerically stable
    reading of the paper's exp(A_t) weighting): mean over all rows of all
    heads of KL(softmax(A_t) || softmax(A_s))."""
    p_t = jax.nn.softmax(t_logits, axis=-1)
    lp_t = jax.nn.log_softmax(t_logits, axis=-1)
    lp_s = jax.nn.log_softmax(s_logits, axis=-1)
    kl = jnp.sum(p_t * (lp_t - lp_s), axis=-1)  # (B, H, n)
    return jnp.mean(kl)


def kl_output(z_t, z_s):
    """Eq. 10 with softmax-normalized teacher weights, summed over classes,
    mean over the batch."""
    p_t = jax.nn.softmax(z_t, axis=-1)
    lp_t = jax.nn.log_softmax(z_t, axis=-1)
    lp_s = jax.nn.log_softmax(z_s, axis=-1)
    return jnp.mean(jnp.sum(p_t * (lp_t - lp_s), axis=-1))


def distill_forward(
    s_params: Params,
    t_params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    variant: str,
    *,
    ste: bool,
    c,
    outer_mult,
    sigma_q,
    sigma_k,
    n_top=None,
):
    """Run teacher (standard) and student (variant) in one layer scan.

    Returns (z_s, z_t, kl_att_mean). The per-layer KL contribution is
    reduced inside the scan so peak memory stays O(B*H*n^2) for ONE layer.
    """
    c = jnp.asarray(c, jnp.float32)
    outer_mult = jnp.asarray(outer_mult, jnp.float32)

    h_t = embed(t_params, x, cfg)
    h_s = embed(s_params, x, cfg)

    t_stack = _stacked_layers(t_params)
    s_stack = _stacked_layers(s_params)

    def body(carry, xs):
        h_t, h_s = carry
        lp_t, lp_s, sq, sk = xs
        h_t, att_t = _layer(
            h_t, lp_t, cfg, "standard",
            ste=True, c=c, outer_mult=outer_mult, sq=sq, sk=sk,
            n_top=n_top, use_pallas=False,
        )
        h_s, att_s = _layer(
            h_s, lp_s, cfg, variant,
            ste=ste, c=c, outer_mult=outer_mult, sq=sq, sk=sk,
            n_top=n_top, use_pallas=False,
        )
        kl = kl_attention_rows(att_t, att_s)
        return (h_t, h_s), kl

    (h_t, h_s), kls = jax.lax.scan(
        body, (h_t, h_s), (t_stack, s_stack, sigma_q, sigma_k)
    )

    def head(params, h):
        h = layer_norm(h, params["lnf_g"], params["lnf_b"])
        return h[:, 0, :] @ params["head_w"] + params["head_b"]

    z_t = head(t_params, h_t)
    z_s = head(s_params, h_s)
    return z_s, z_t, jnp.mean(kls)
