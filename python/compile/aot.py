"""AOT artifact builder: lower every (config, kind, variant) graph to HLO text.

Emits HLO *text*, NOT serialized HloModuleProto — jax >= 0.5 writes protos
with 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs:
  artifacts/<config>__<name>.hlo.txt   one per artifact
  artifacts/manifest.json              the Rust-side contract: model
      configs, parameter layout (name/shape/init), artifact signatures.

Usage:
  python -m compile.aot --out-dir ../artifacts [--only tinyglue] [--list]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
from typing import Any, Dict, List

import jax
from jax._src.lib import xla_client as xc

from . import model, steps
from .model import ModelConfig

# ---------------------------------------------------------------------------
# Experiment configuration registry (mirrors DESIGN.md §7)
# ---------------------------------------------------------------------------

# Batch sizes chosen for the single-core CPU testbed; EXPERIMENTS.md records
# the scale. n_top defaults follow the paper: 30 @ n=256 context scaled
# linearly (§3.2 / §4.3).

CONFIGS: Dict[str, Dict[str, Any]] = {
    # GLUE analog: BERT-shaped token-mode encoder (paper §4.1, Table 1)
    "tinyglue": {
        "model": ModelConfig(
            n_layers=2, d_model=64, n_heads=4, d_ff=128,
            n_ctx=128, n_classes=4, vocab=256, n_top=15, block_q=64,
        ),
        "train_batch": 16,
        "eval_batch": 16,
    },
    # ImageNet analog, DeiT-B stand-in (paper §4.2, Table 2)
    "vision_base": {
        "model": ModelConfig(
            n_layers=4, d_model=96, n_heads=8, d_ff=192,
            n_ctx=65, n_classes=8, vocab=0, input_dim=48, n_top=10, block_q=65,
        ),
        "train_batch": 16,
        "eval_batch": 16,
    },
    # ImageNet analog, DeiT-T stand-in — also the Figure-3 N-sweep subject
    "vision_tiny": {
        "model": ModelConfig(
            n_layers=2, d_model=48, n_heads=4, d_ff=96,
            n_ctx=65, n_classes=8, vocab=0, input_dim=48, n_top=10, block_q=65,
        ),
        "train_batch": 16,
        "eval_batch": 16,
    },
}

# QuALITY analog at powers-of-two context lengths (paper §4.3, Figure 5).
# N scales linearly with context: 15 @ 128 ... 120 @ 1024 (paper's ratio).
_LONGQA_BATCH = {128: 16, 256: 16, 512: 8, 1024: 4}
for _n, _b in _LONGQA_BATCH.items():
    CONFIGS[f"longqa_{_n}"] = {
        "model": ModelConfig(
            n_layers=2, d_model=64, n_heads=4, d_ff=128,
            n_ctx=_n, n_classes=4, vocab=256,
            n_top=max(1, 15 * _n // 128), block_q=min(64, _n),
        ),
        "train_batch": _b,
        "eval_batch": _b,
    }


def artifact_plan(config_name: str) -> List[Dict[str, Any]]:
    """Artifacts to build for one config. Fields consumed by Rust."""
    entry = CONFIGS[config_name]
    tb, eb = entry["train_batch"], entry["eval_batch"]
    plan = [
        {"name": "teacher_step", "kind": "teacher_step", "variant": "standard", "ste": True, "pallas": False, "batch": tb},
        {"name": "calib", "kind": "calib", "variant": "standard", "ste": True, "pallas": False, "batch": tb},
        {"name": "distill_had_tanh", "kind": "distill_step", "variant": "had", "ste": False, "pallas": False, "batch": tb},
        {"name": "distill_had_ste", "kind": "distill_step", "variant": "had", "ste": True, "pallas": False, "batch": tb},
        {"name": "fwd_standard", "kind": "fwd", "variant": "standard", "ste": True, "pallas": False, "batch": eb},
        {"name": "fwd_had", "kind": "fwd", "variant": "had", "ste": True, "pallas": True, "batch": eb},
    ]
    if config_name in ("tinyglue", "vision_base", "vision_tiny"):
        plan += [
            {"name": "distill_sab_tanh", "kind": "distill_step", "variant": "sab", "ste": False, "pallas": False, "batch": tb},
            {"name": "distill_sab_ste", "kind": "distill_step", "variant": "sab", "ste": True, "pallas": False, "batch": tb},
            {"name": "distill_bit_ste", "kind": "distill_step", "variant": "bit", "ste": True, "pallas": False, "batch": tb},
            {"name": "fwd_bit", "kind": "fwd", "variant": "bit", "ste": True, "pallas": False, "batch": eb},
            {"name": "fwd_sab", "kind": "fwd", "variant": "sab", "ste": True, "pallas": False, "batch": eb},
        ]
    if config_name == "vision_tiny":
        # Figure 3: full-precision student with top-N only (runtime N).
        plan += [
            {"name": "distill_fptopn", "kind": "distill_step", "variant": "fp_topn", "ste": True, "pallas": False, "batch": tb},
            {"name": "fwd_fptopn", "kind": "fwd", "variant": "fp_topn", "ste": True, "pallas": False, "batch": eb},
        ]
    if config_name.startswith("longqa"):
        # Figure 1: single-request latency with and without the O(n^2) block.
        plan += [
            {"name": "fwd_standard_b1", "kind": "fwd", "variant": "standard", "ste": True, "pallas": False, "batch": 1},
            {"name": "fwd_noattn_b1", "kind": "fwd", "variant": "noattn", "ste": True, "pallas": False, "batch": 1},
            {"name": "fwd_had_b1", "kind": "fwd", "variant": "had", "ste": True, "pallas": True, "batch": 1},
        ]
    return plan


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def build_fn(cfg: ModelConfig, art: Dict[str, Any]):
    kind = art["kind"]
    if kind == "teacher_step":
        return steps.make_teacher_step(cfg)
    if kind == "distill_step":
        return steps.make_distill_step(cfg, art["variant"], art["ste"])
    if kind == "fwd":
        return steps.make_fwd(cfg, art["variant"], use_pallas=art["pallas"])
    if kind == "calib":
        return steps.make_calib(cfg)
    raise ValueError(kind)


def to_hlo_text(fn, example_args) -> str:
    # keep_unused=True: the rust caller supplies EVERY signature input
    # positionally (params the graph doesn't touch included — e.g. the
    # classifier head in the calib graph, or n_top in pallas-fwd graphs).
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(specs) -> List[Dict[str, Any]]:
    return [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs]


def build_all(out_dir: str, only: str | None = None, list_only: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest: Dict[str, Any] = {"version": 1, "configs": {}, "artifacts": []}
    t0 = time.time()
    n_built = 0
    for config_name, entry in CONFIGS.items():
        if only and only not in config_name:
            continue
        cfg: ModelConfig = entry["model"]
        manifest["configs"][config_name] = {
            "model": cfg.to_dict(),
            "train_batch": entry["train_batch"],
            "eval_batch": entry["eval_batch"],
            "params": [
                {"name": n, "shape": list(sh), "init": init}
                for n, sh, init in model.param_specs(cfg)
            ],
        }
        for art in artifact_plan(config_name):
            fname = f"{config_name}__{art['name']}.hlo.txt"
            example = steps.example_inputs(cfg, art["kind"], art["batch"])
            record = {
                "config": config_name,
                "file": fname,
                "inputs": _sig(example),
                **art,
            }
            manifest["artifacts"].append(record)
            if list_only:
                print(fname)
                continue
            fn = build_fn(cfg, art)
            text = to_hlo_text(fn, example)
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            record["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
            record["hlo_bytes"] = len(text)
            n_built += 1
            print(f"[aot] {fname}  ({len(text) / 1e6:.2f} MB, {time.time() - t0:.0f}s elapsed)")
    if not list_only:
        with open(os.path.join(out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"[aot] wrote {n_built} artifacts + manifest.json in {time.time() - t0:.0f}s")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on config name")
    ap.add_argument("--list", action="store_true", help="list artifact names only")
    args = ap.parse_args()
    build_all(args.out_dir, args.only, args.list)


if __name__ == "__main__":
    main()
